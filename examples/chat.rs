//! Interactive chat over the speculative-decoding stack: type SynthChat
//! instructions (in-vocab words), watch the draft+target pair answer, with
//! per-turn speculation statistics.
//!
//! ```sh
//! cargo run --release --example chat
//! > tell me about <topic word>     (see `--list-words`)
//! ```

use std::io::{BufRead, Write};
use std::sync::Arc;

use specd::artifacts::Manifest;
use specd::cli::Args;
use specd::config::SamplingConfig;
use specd::rng::Pcg64;
use specd::runtime::Runtime;
use specd::spec::SpecDecoder;
use specd::tokenizer::{Tokenizer, EOS};

fn main() -> specd::Result<()> {
    let args = Args::new("chat", "interactive speculative-decoding chat")
        .opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("draft", "", "draft model (default: best tvdpp checkpoint)")
        .opt("gamma", "3", "speculation depth")
        .opt("temperature", "0.6", "sampling temperature")
        .opt("top-p", "0.9", "nucleus mass")
        .opt("max-new", "48", "max new tokens per turn")
        .flag("list-words", "print the vocabulary and exit")
        .parse()?;

    let manifest = Manifest::load(args.str("artifacts"))?;
    let tokenizer = Tokenizer::load(&manifest.vocab_path())?;

    if args.flag("list-words") {
        let mut words: Vec<&str> =
            (5..tokenizer.vocab_size() as u32).map(|i| tokenizer.word(i)).collect();
        words.sort_unstable();
        for chunk in words.chunks(10) {
            println!("{}", chunk.join(" "));
        }
        return Ok(());
    }

    let rt = Arc::new(Runtime::new()?);
    let draft_arch = rt.load_arch(&manifest, "draft")?;
    let target_arch = rt.load_arch(&manifest, "target")?;
    let target = rt.load_model(&manifest, &target_arch, "target")?;
    let draft_name = if args.str("draft").is_empty() {
        manifest
            .draft_models()
            .into_iter()
            .filter(|n| n.contains("tvdpp")).max()
            .unwrap_or_else(|| "draft_base".to_string())
    } else {
        args.str("draft").to_string()
    };
    let draft = rt.load_model(&manifest, &draft_arch, &draft_name)?;
    let gamma = args.usize("gamma")?;
    let decoder = SpecDecoder::new(&draft, &target, gamma)?;
    let cfg = SamplingConfig::random(
        args.f64("temperature")? as f32,
        args.f64("top-p")? as f32,
        1,
    );

    println!("specd chat — draft {draft_name}, gamma {gamma}. Ctrl-D to exit.");
    println!("(SynthChat is a synthetic language; try `--list-words` for vocabulary)");
    let stdin = std::io::stdin();
    let mut turn = 0u64;
    loop {
        print!("> ");
        std::io::stdout().flush().ok();
        let mut line = String::new();
        if stdin.lock().read_line(&mut line).unwrap_or(0) == 0 {
            println!();
            break;
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let instr = match tokenizer.encode(line) {
            Ok(t) => t,
            Err(e) => {
                println!("  (cannot tokenize: {e})");
                continue;
            }
        };
        let prompt = tokenizer.chat_prompt(&instr);
        let mut rng = Pcg64::new(0xC4A7 + turn);
        turn += 1;
        let t0 = std::time::Instant::now();
        match decoder.generate(&prompt, args.usize("max-new")?, &cfg, &mut rng) {
            Ok((out, stats)) => {
                let shown: Vec<u32> = out.iter().copied().filter(|&t| t != EOS).collect();
                println!("{}", tokenizer.decode(&shown));
                println!(
                    "  [{} tok in {:.2}s | tau {:.2} | acceptance {:.2}]",
                    shown.len(),
                    t0.elapsed().as_secs_f64(),
                    stats.block_efficiency(),
                    stats.acceptance_rate()
                );
            }
            Err(e) => println!("  (generation failed: {e})"),
        }
    }
    Ok(())
}

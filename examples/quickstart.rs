//! Quickstart: load the artifact bundle, run one prompt through
//! speculative decoding, and compare with the autoregressive baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use specd::artifacts::Manifest;
use specd::baseline::ArDecoder;
use specd::config::SamplingConfig;
use specd::metrics::mbsu;
use specd::rng::Pcg64;
use specd::runtime::Runtime;
use specd::spec::SpecDecoder;
use specd::tokenizer::Tokenizer;
use specd::workload::EvalSuite;

fn main() -> specd::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let manifest = Manifest::load(&dir)?;

    // 1. Bring up the PJRT runtime and compile the two architectures.
    let rt = Arc::new(Runtime::new()?);
    println!("PJRT platform: {}", rt.platform());
    let draft_arch = rt.load_arch(&manifest, "draft")?;
    let target_arch = rt.load_arch(&manifest, "target")?;

    // 2. Load weights: the chat-tuned target + the TVD++-aligned draft.
    let target = rt.load_model(&manifest, &target_arch, "target")?;
    let draft_name = manifest
        .draft_models()
        .into_iter()
        .filter(|n| n.contains("tvdpp")).max()
        .unwrap_or_else(|| "draft_base".to_string());
    let draft = rt.load_model(&manifest, &draft_arch, &draft_name)?;
    println!(
        "target: {} params | draft: {} ({} params, c = {:.3}%)",
        target.params,
        draft.name,
        draft.params,
        draft.c_ratio * 100.0
    );

    // 3. Pick an open-ended prompt and decode speculatively (gamma = 3).
    let tokenizer = Tokenizer::load(&manifest.vocab_path())?;
    let suite = EvalSuite::load(&manifest.root.join("eval_prompts.json"))?;
    let ex = &suite.take("dolly", 1)?[0];
    let cfg = SamplingConfig::for_task("dolly", 42);
    let gamma = 3;

    println!("\nprompt: {}", tokenizer.decode(&ex.prompt));

    let spec = SpecDecoder::new(&draft, &target, gamma)?;
    let mut rng = Pcg64::new(42);
    let t0 = std::time::Instant::now();
    let (out, stats) = spec.generate(&ex.prompt, 48, &cfg, &mut rng)?;
    let sd_secs = t0.elapsed().as_secs_f64();
    println!("speculative output: {}", tokenizer.decode(&out));

    // 4. Baseline for comparison.
    let ar = ArDecoder::new(&target);
    let mut rng = Pcg64::new(42);
    let (ar_out, _, ar_rate) = ar.generate(&ex.prompt, 48, &cfg, &mut rng)?;
    println!("baseline output:    {}", tokenizer.decode(&ar_out));

    let tau = stats.block_efficiency();
    println!("\nblock efficiency tau = {tau:.3} (max {})", gamma + 1);
    println!("acceptance rate      = {:.3}", stats.acceptance_rate());
    println!("MBSU                 = {:.3}", mbsu(tau, draft.c_ratio, gamma));
    println!(
        "token rate           = {:.1} tok/s SD vs {:.1} tok/s AR ({:.2}x)",
        out.len() as f64 / sd_secs,
        ar_rate.tokens_per_sec(),
        (out.len() as f64 / sd_secs) / ar_rate.tokens_per_sec()
    );
    Ok(())
}

//! Admission-path microbench (the PR 5 perf artifact): fused admission
//! waves vs the per-sequence prefill+pack path, and the TTFT-vs-ITL trade
//! of the `--prefill-budget` interleaving knob. Writes a machine-readable
//! `BENCH_pr5.json` (CI uploads it when present).
//!
//! Two parts:
//!
//! 1. **Admission dispatch sweep** — for each wave width N, admit the same
//!    ragged prompt mix (short-chat + exact-boundary + long-document)
//!    once per-sequence (`start` + `adopt`: Σ ceil(L_i/block) chunk
//!    dispatches + N packs) and once as a wave (`admit_wave`:
//!    O(ceil(L_max/block)) fused dispatches, zero packs). Hard-asserts
//!    the wave bound, mirroring `dispatch_microbench`'s fused-step gate.
//! 2. **Prefill-budget sweep** — replays one bursty Poisson trace with a
//!    short/long prompt-length mixture through the coordinator per
//!    budget value and records TTFT/latency percentiles, throughput and
//!    the admission-wave counters, making the chunked-prefill
//!    interleaving trade-off measurable.
//!
//! ```sh
//! cargo run --release --example admission_microbench -- \
//!     --artifacts artifacts --lanes 1,4,8 --budgets 0,32,128 --out BENCH_pr5.json
//! ```

use std::sync::Arc;

use specd::artifacts::Manifest;
use specd::benchkit::write_bench_json;
use specd::cli::Args;
use specd::config::{RunConfig, SamplingConfig};
use specd::coordinator::{Coordinator, Request, Response};
use specd::exec;
use specd::json::Value;
use specd::metrics::ServeMetrics;
use specd::runtime::{Entry, Runtime};
use specd::spec::SpecDecoder;
use specd::workload::{build_trace, parse_len_mix, stretch_prompt, EvalSuite, TraceConfig};

/// The ragged admission mix: short-chat, exact-boundary and long-document
/// prompts built from real suite prompts.
fn ragged_prompts(suite: &EvalSuite, block: usize, n: usize) -> specd::Result<Vec<Vec<u32>>> {
    let exs = suite.take("dolly", n)?;
    Ok(exs
        .iter()
        .enumerate()
        .map(|(i, ex)| match i % 4 {
            0 => stretch_prompt(&ex.prompt, (block / 4).max(1)),
            1 => stretch_prompt(&ex.prompt, 2 * block + 3),
            2 => stretch_prompt(&ex.prompt, block),
            _ => ex.prompt.clone(),
        })
        .collect())
}

fn main() -> specd::Result<()> {
    let args = Args::new("admission_microbench", "wave vs per-sequence admission microbench")
        .opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("draft", "", "draft model (default: best tvdpp checkpoint)")
        .opt("gamma", "3", "speculation depth")
        .opt("lanes", "1,4,8", "comma-separated admission-wave widths")
        .opt("budgets", "0,32,128", "prefill-budget sweep (tokens/iteration; 0 = unbounded)")
        .opt("requests", "24", "budget sweep: requests per replay")
        .opt("rate", "16.0", "budget sweep: Poisson arrival rate (bursty)")
        .opt("max-new", "16", "budget sweep: new tokens per request")
        .opt("max-slots", "4", "budget sweep: KV slot pool size")
        .opt("len-mix", "8:0.6,96:0.4", "budget sweep: prompt-length mixture")
        .opt("seed", "0", "trace seed")
        .opt("out", "BENCH_pr5.json", "machine-readable output artifact")
        .opt("trace-out", "", "write the budget sweep's flight-recorder ring as Chrome trace JSON")
        .parse()?;

    let trace_out = args.str("trace-out").to_string();
    if !trace_out.is_empty() {
        specd::trace::enable(specd::trace::DEFAULT_CAPACITY);
    }

    let manifest = Manifest::load(args.str("artifacts"))?;
    let rt = Arc::new(Runtime::new()?);
    let draft_arch = rt.load_arch(&manifest, "draft")?;
    let target_arch = rt.load_arch(&manifest, "target")?;
    let target = rt.load_model(&manifest, &target_arch, "target")?;
    let draft_name = if args.str("draft").is_empty() {
        manifest
            .draft_models()
            .into_iter()
            .filter(|n| n.contains("tvdpp"))
            .max()
            .unwrap_or_else(|| "draft_base".to_string())
    } else {
        args.str("draft").to_string()
    };
    let draft = rt.load_model(&manifest, &draft_arch, &draft_name)?;
    let suite = EvalSuite::load(&manifest.root.join("eval_prompts.json"))?;
    let gamma = args.usize("gamma")?;
    let decoder = SpecDecoder::new(&draft, &target, gamma)?;
    let block = target.arch.block(Entry::Prefill);
    let batched_available = decoder.batched_ctx()?.is_some();
    if !batched_available {
        eprintln!("note: bundle has no batched entry points; wave rows will be skipped");
    }

    // ---- part 1: admission dispatch sweep --------------------------------
    let mut rows = Vec::new();
    let lane_counts: Vec<usize> = args
        .list("lanes")
        .iter()
        .map(|s| s.parse().map_err(|_| specd::Error::Cli(format!("--lanes: bad value '{s}'"))))
        .collect::<specd::Result<_>>()?;
    for &n in &lane_counts {
        let prompts = ragged_prompts(&suite, block, n)?;
        let tokens: usize = prompts.iter().map(Vec::len).sum();
        let l_max = prompts.iter().map(Vec::len).max().unwrap_or(0);
        let sum_chunks: usize = prompts.iter().map(|p| p.len().div_ceil(block)).sum();

        // Per-sequence baseline: owned prefill, then pack into the arena.
        let mut ctx = decoder.batched_ctx()?;
        let d0 = decoder.dispatch_count();
        let mut sessions = Vec::new();
        for p in &prompts {
            let mut s = decoder.start(p)?;
            if let Some(c) = ctx.as_mut() {
                decoder.adopt(c, &mut s)?;
            }
            sessions.push(s);
        }
        let per_seq = decoder.dispatch_count() - d0;
        if let Some(c) = ctx.as_mut() {
            for s in sessions.iter_mut() {
                decoder.release(c, s);
            }
        }
        drop(sessions);
        rows.push(Value::obj(vec![
            ("mode", Value::Str("per_seq".to_string())),
            ("lanes", Value::Num(n as f64)),
            ("prompt_tokens", Value::Num(tokens as f64)),
            ("sum_chunks", Value::Num(sum_chunks as f64)),
            ("dispatches", Value::Num(per_seq as f64)),
            ("dispatches_per_lane", Value::Num(per_seq as f64 / n.max(1) as f64)),
        ]));

        // Fused wave over the identical prompts.
        if let Some(mut c) = decoder.batched_ctx()? {
            if n > c.available() {
                eprintln!("note: lanes={n} exceeds arena capacity {}; skipping", c.available());
                continue;
            }
            let d0 = decoder.dispatch_count();
            let mut sessions = decoder.admit_wave(&mut c, prompts.clone())?;
            let wave = decoder.dispatch_count() - d0;
            for s in sessions.iter_mut() {
                decoder.release(&mut c, s);
            }
            let chunks = l_max.div_ceil(block) as u64;
            // The acceptance gate: O(ceil(L_max/block)) fused dispatches
            // (each chunk = one prefill per model + at most one extract
            // readback each), ZERO packs, for ANY wave width.
            assert!(
                wave <= 4 * chunks,
                "wave of {n} issued {wave} dispatches (> O(ceil(L_max/block)) bound {})",
                4 * chunks
            );
            println!(
                "admission lanes={n}: per_seq={per_seq} wave={wave} dispatches \
                 (Σchunks={sum_chunks}, ceil(Lmax/block)={chunks})"
            );
            rows.push(Value::obj(vec![
                ("mode", Value::Str("wave".to_string())),
                ("lanes", Value::Num(n as f64)),
                ("prompt_tokens", Value::Num(tokens as f64)),
                ("max_chunks", Value::Num(chunks as f64)),
                ("dispatches", Value::Num(wave as f64)),
                ("dispatches_per_lane", Value::Num(wave as f64 / n.max(1) as f64)),
            ]));
        }
    }

    // ---- part 2: prefill-budget sweep ------------------------------------
    let mut budget_rows = Vec::new();
    let budgets: Vec<usize> = args
        .list("budgets")
        .iter()
        .map(|s| s.parse().map_err(|_| specd::Error::Cli(format!("--budgets: bad value '{s}'"))))
        .collect::<specd::Result<_>>()?;
    let trace_cfg = TraceConfig {
        rate: args.f64("rate")?,
        n_requests: args.usize("requests")?,
        max_new: args.usize("max-new")?,
        seed: args.u64("seed")?,
        prompt_len_mix: parse_len_mix(args.str("len-mix"))?,
        ..Default::default()
    };
    let trace = build_trace(&suite, &trace_cfg)?;
    for &budget in &budgets {
        let cfg = RunConfig {
            gamma,
            max_slots: args.usize("max-slots")?,
            max_new_tokens: trace_cfg.max_new,
            prefill_budget: budget,
            ..RunConfig::default()
        };
        let decoder = SpecDecoder::new(&draft, &target, gamma)?;
        let coord = Coordinator::new(decoder, cfg)?;
        let m = replay(&coord, &trace)?;
        let q = |st: &Option<specd::benchkit::Stats>, f: fn(&specd::benchkit::Stats) -> f64| {
            st.as_ref().map(f).unwrap_or(0.0)
        };
        let (ttft, lat) = (m.ttft_stats(), m.latency_stats());
        println!(
            "budget={budget}: ttft p50={:.0}ms p90={:.0}ms | latency p50={:.0}ms | \
             {:.1} tok/s | waves={} (mean {:.1} lanes)",
            q(&ttft, |s| s.p50) * 1e3,
            q(&ttft, |s| s.p90) * 1e3,
            q(&lat, |s| s.p50) * 1e3,
            m.throughput_tok_s(),
            m.prefill_waves,
            m.mean_wave_lanes(),
        );
        budget_rows.push(Value::obj(vec![
            ("prefill_budget", Value::Num(budget as f64)),
            ("ttft_p50", Value::Num(q(&ttft, |s| s.p50))),
            ("ttft_p90", Value::Num(q(&ttft, |s| s.p90))),
            ("latency_p50", Value::Num(q(&lat, |s| s.p50))),
            ("tokens_per_sec", Value::Num(m.throughput_tok_s())),
            ("batch_iterations", Value::Num(m.batch_iterations as f64)),
            ("prefill_waves", Value::Num(m.prefill_waves as f64)),
            ("mean_wave_lanes", Value::Num(m.mean_wave_lanes())),
            ("prefill_dispatches", Value::Num(m.prefill_dispatches as f64)),
            ("prefill_tokens", Value::Num(m.prefill_tokens as f64)),
        ]));
    }

    let artifact = Value::obj(vec![
        ("bench", Value::Str("admission_microbench".to_string())),
        ("draft", Value::Str(draft_name)),
        ("gamma", Value::Num(gamma as f64)),
        ("prefill_block", Value::Num(block as f64)),
        ("batched_available", Value::Bool(batched_available)),
        ("len_mix", Value::Str(args.str("len-mix").to_string())),
        ("admission_rows", Value::Arr(rows)),
        ("budget_rows", Value::Arr(budget_rows)),
    ]);
    write_bench_json(args.str("out"), &artifact)?;
    println!("wrote {}", args.str("out"));
    if !trace_out.is_empty() {
        specd::trace::write_chrome_trace(&trace_out)?;
        println!("trace: {trace_out}");
    }
    Ok(())
}

/// Feed the trace through the coordinator with real arrival timing (same
/// shape as serve_benchmark's replay; queue wait counts via `submitted`).
fn replay(
    coord: &Coordinator,
    trace: &[specd::workload::TraceRequest],
) -> specd::Result<ServeMetrics> {
    let (req_tx, req_rx) = exec::bounded::<Request>(64);
    let (resp_tx, resp_rx) = exec::bounded::<Response>(256);
    let trace_owned: Vec<specd::workload::TraceRequest> = trace.to_vec();
    let client = std::thread::spawn(move || {
        let t0 = std::time::Instant::now();
        for (i, r) in trace_owned.into_iter().enumerate() {
            if let Some(wait) = r.arrival.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let mut req = Request::new(
                i as u64,
                r.prompt,
                r.max_new,
                SamplingConfig::for_task(&r.task, i as u64),
            );
            req.submitted = Some(std::time::Instant::now());
            let _ = req_tx.send(req);
        }
    });
    let metrics = coord.serve(req_rx, resp_tx)?;
    client.join().expect("client thread");
    let mut failures = 0;
    while let Some(r) = resp_rx.try_recv() {
        if r.error.is_some() {
            failures += 1;
        }
    }
    if failures > 0 {
        eprintln!("warning: {failures} failed requests");
    }
    Ok(metrics)
}

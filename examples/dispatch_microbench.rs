//! Per-lane vs fused-batched dispatch microbench (the PR 4 perf artifact).
//!
//! Drives `BatchStep` directly — no HTTP, no arrival process — over
//! N ∈ `--lanes` concurrent greedy sequences, once with the fused
//! `[B, T]` dispatch path (`BatchedCtx`) and once with per-lane dispatch,
//! and records tokens/s, dispatches per block and batch occupancy into a
//! machine-readable `BENCH_pr4.json` (the first datapoint of the perf
//! trajectory; CI uploads it when present).
//!
//! ```sh
//! cargo run --release --example dispatch_microbench -- \
//!     --artifacts artifacts --gamma 3 --lanes 1,4,8 --out BENCH_pr4.json
//! ```
//!
//! The fused path must issue O(γ + 2) dispatches per step regardless of N
//! (per-lane issues O(N·(γ + 2))); the bench asserts that bound and warns
//! if batched output diverges from per-lane output (they are pinned equal
//! in rust/tests/batched_integration.rs).

use std::sync::Arc;
use std::time::Instant;

use specd::artifacts::Manifest;
use specd::batch::{BatchStep, Lane, LaneOutcome};
use specd::benchkit::{write_bench_json, Table};
use specd::cli::Args;
use specd::config::SamplingConfig;
use specd::json::Value;
use specd::rng::Pcg64;
use specd::runtime::Runtime;
use specd::spec::SpecDecoder;
use specd::workload::EvalSuite;

struct Row {
    mode: &'static str,
    lanes: usize,
    steps: u64,
    dispatches: u64,
    tokens: usize,
    wall: f64,
    lane_steps: usize,
    outputs: Vec<Vec<u32>>,
}

impl Row {
    fn dispatches_per_block(&self) -> f64 {
        if self.lane_steps == 0 {
            0.0
        } else {
            self.dispatches as f64 / self.lane_steps as f64
        }
    }

    fn occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.steps as f64
        }
    }

    fn tokens_per_sec(&self) -> f64 {
        if self.wall == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.wall
        }
    }

    fn json(&self) -> Value {
        Value::obj(vec![
            ("mode", Value::Str(self.mode.to_string())),
            ("lanes", Value::Num(self.lanes as f64)),
            ("steps", Value::Num(self.steps as f64)),
            ("dispatches", Value::Num(self.dispatches as f64)),
            ("dispatches_per_step", Value::Num(self.dispatches as f64 / self.steps.max(1) as f64)),
            ("dispatches_per_block", Value::Num(self.dispatches_per_block())),
            ("tokens", Value::Num(self.tokens as f64)),
            ("tokens_per_sec", Value::Num(self.tokens_per_sec())),
            ("batch_occupancy", Value::Num(self.occupancy())),
            ("wall_seconds", Value::Num(self.wall)),
        ])
    }
}

fn run_config(
    decoder: &SpecDecoder<'_>,
    suite: &EvalSuite,
    n: usize,
    fused: bool,
    max_new: usize,
) -> specd::Result<Row> {
    let mut ctx = if fused { decoder.batched_ctx()? } else { None };
    let examples = suite.take("dolly", n)?;
    let sampling = SamplingConfig::greedy();
    let mut sessions = Vec::with_capacity(n);
    let mut rngs = Vec::with_capacity(n);
    for (i, ex) in examples.iter().enumerate() {
        let mut s = decoder.start(&ex.prompt)?;
        if let Some(c) = ctx.as_mut() {
            decoder.adopt(c, &mut s)?;
        }
        sessions.push(s);
        rngs.push(Pcg64::with_stream(i as u64, 0xbe7c));
    }

    let t0 = Instant::now();
    let (mut steps, mut dispatches, mut lane_steps) = (0u64, 0u64, 0usize);
    loop {
        let mut lanes: Vec<Lane<'_>> = sessions
            .iter_mut()
            .zip(rngs.iter_mut())
            .filter(|(s, _)| !s.finished && s.generated().len() < max_new)
            .map(|(s, rng)| Lane { session: s, sampling, rng })
            .collect();
        if lanes.is_empty() {
            break;
        }
        let (outcomes, t) = BatchStep::run(decoder, ctx.as_mut(), &mut lanes);
        for o in &outcomes {
            if let LaneOutcome::Failed(e) = o {
                return Err(specd::Error::msg(format!("lane failed: {e}")));
            }
        }
        steps += 1;
        dispatches += t.dispatches;
        lane_steps += t.lanes;
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut outputs = Vec::with_capacity(n);
    let mut tokens = 0usize;
    for s in &mut sessions {
        let mut out = s.generated().to_vec();
        out.truncate(max_new);
        tokens += out.len();
        outputs.push(out);
    }
    if let Some(c) = ctx.as_mut() {
        for s in &mut sessions {
            decoder.release(c, s);
        }
    }
    Ok(Row {
        mode: if fused { "batched" } else { "per_lane" },
        lanes: n,
        steps,
        dispatches,
        tokens,
        wall,
        lane_steps,
        outputs,
    })
}

/// Tracing-off overhead gate (hard-asserted): with the recorder disabled
/// every trace site costs one relaxed atomic load and an early return.
/// Measure that real disabled-path cost, bill it against each row's
/// measured wall time at the row's actual site density (2 calls per
/// dispatch, 2 per phase span x3 phases + 2 for the scheduler's iteration
/// span per step — counted even though this driver issues only the
/// phases — plus 1 `req_block` guard per emitted block), and require the
/// delta to stay under 1% of the row's tokens/s. Returns
/// (ns_per_site, worst_fraction) for the bench artifact.
fn assert_trace_overhead(rows: &[Row]) -> (f64, f64) {
    assert!(!specd::trace::enabled(), "microbench must run with tracing disabled");
    let reps: u64 = 2_000_000;
    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..reps {
        let t = specd::trace::begin();
        acc = acc.wrapping_add(t);
        specd::trace::dispatch(t, specd::trace::DispatchKind::Decode, 1, 0);
    }
    std::hint::black_box(acc);
    // Two site calls per rep (begin + span record).
    let ns_per_site = t0.elapsed().as_nanos() as f64 / (2 * reps) as f64;
    let mut worst = 0.0f64;
    for r in rows {
        if r.wall == 0.0 {
            continue;
        }
        let calls = 2.0 * r.dispatches as f64 + 8.0 * r.steps as f64 + r.lane_steps as f64;
        let frac = calls * ns_per_site / (r.wall * 1e9);
        assert!(
            frac <= 0.01,
            "tracing-off sites cost {:.3}% of {} lanes={} wall time (> 1% gate; \
             {ns_per_site:.1} ns/site x {calls:.0} calls)",
            frac * 100.0,
            r.mode,
            r.lanes,
        );
        worst = worst.max(frac);
    }
    (ns_per_site, worst)
}

/// Telemetry-off overhead gate (hard-asserted), the mirror of the tracing
/// gate above: with the snapshot ring disabled every feed site costs one
/// relaxed atomic load and an early return. Measure that disabled-path
/// cost, bill it at the scheduler's site density (one `on_block` per
/// emitted block, one `on_iteration` per step, one `on_ttft` per lane)
/// and require the delta to stay under 1% of each row's wall time.
/// Returns (ns_per_site, worst_fraction) for the bench artifact.
fn assert_telemetry_overhead(rows: &[Row]) -> (f64, f64) {
    let tl = specd::telemetry::Telemetry::off();
    assert!(!tl.enabled(), "microbench needs the disabled telemetry handle");
    let reps: u64 = 2_000_000;
    let sample = specd::telemetry::IterSample::default();
    let t0 = Instant::now();
    for i in 0..reps {
        tl.on_block(0, 2, 3, 3, None);
        std::hint::black_box(i);
        tl.on_iteration(&sample);
    }
    // Two site calls per rep (one block feed + one iteration feed).
    let ns_per_site = t0.elapsed().as_nanos() as f64 / (2 * reps) as f64;
    let mut worst = 0.0f64;
    for r in rows {
        if r.wall == 0.0 {
            continue;
        }
        let calls = r.lane_steps as f64 + r.steps as f64 + r.lanes as f64;
        let frac = calls * ns_per_site / (r.wall * 1e9);
        assert!(
            frac <= 0.01,
            "telemetry-off sites cost {:.3}% of {} lanes={} wall time (> 1% gate; \
             {ns_per_site:.1} ns/site x {calls:.0} calls)",
            frac * 100.0,
            r.mode,
            r.lanes,
        );
        worst = worst.max(frac);
    }
    (ns_per_site, worst)
}

fn main() -> specd::Result<()> {
    let args = Args::new("dispatch_microbench", "per-lane vs fused-batched dispatch microbench")
        .opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("draft", "", "draft model (default: best tvdpp checkpoint)")
        .opt("gamma", "3", "speculation depth")
        .opt("max-new", "24", "new tokens per lane")
        .opt("lanes", "1,4,8", "comma-separated lane counts (the occupancy sweep)")
        .opt("out", "BENCH_pr4.json", "machine-readable output artifact")
        .parse()?;

    let manifest = Manifest::load(args.str("artifacts"))?;
    let rt = Arc::new(Runtime::new()?);
    let draft_arch = rt.load_arch(&manifest, "draft")?;
    let target_arch = rt.load_arch(&manifest, "target")?;
    let target = rt.load_model(&manifest, &target_arch, "target")?;
    let draft_name = if args.str("draft").is_empty() {
        manifest.draft_models().into_iter().filter(|n| n.contains("tvdpp")).max()
            .unwrap_or_else(|| "draft_base".to_string())
    } else {
        args.str("draft").to_string()
    };
    let draft = rt.load_model(&manifest, &draft_arch, &draft_name)?;
    let suite = EvalSuite::load(&manifest.root.join("eval_prompts.json"))?;
    let gamma = args.usize("gamma")?;
    let max_new = args.usize("max-new")?;
    let decoder = SpecDecoder::new(&draft, &target, gamma)?;
    let batched_available = decoder.batched_ctx()?.is_some();
    if !batched_available {
        eprintln!("note: bundle has no batched entry points; batched rows will be skipped");
    }

    let lane_counts: Vec<usize> = args
        .str("lanes")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().map_err(|_| specd::Error::Cli(format!("--lanes: bad value '{s}'"))))
        .collect::<specd::Result<_>>()?;

    let mut table = Table::new(&["mode", "lanes", "steps", "disp", "disp/block", "occup", "tok/s"]);
    let mut rows_json = Vec::new();
    let mut all_rows: Vec<Row> = Vec::new();
    for &n in &lane_counts {
        let per_lane = run_config(&decoder, &suite, n, false, max_new)?;
        let mut pair = vec![per_lane];
        if batched_available {
            let batched = run_config(&decoder, &suite, n, true, max_new)?;
            // The fused path's dispatch bill per step is bounded by the
            // block shape alone: <= 2 sync + 2(γ-1) propose + 2 verify
            // launches (extract readbacks included), for ANY occupancy.
            let bound = (2 * gamma + 4) as f64;
            let per_step = batched.dispatches as f64 / batched.steps.max(1) as f64;
            assert!(
                per_step <= bound + 1e-9,
                "fused path issued {per_step:.1} dispatches/step (> O(γ+2) bound {bound})"
            );
            if batched.outputs != pair[0].outputs {
                eprintln!(
                    "warning: batched output != per-lane output at lanes={n} \
                     (numerics drift between single and vmapped executables?)"
                );
            }
            pair.push(batched);
        }
        for r in pair {
            table.row(&[
                r.mode.to_string(),
                r.lanes.to_string(),
                r.steps.to_string(),
                r.dispatches.to_string(),
                format!("{:.2}", r.dispatches_per_block()),
                format!("{:.2}", r.occupancy()),
                format!("{:.1}", r.tokens_per_sec()),
            ]);
            rows_json.push(r.json());
            all_rows.push(r);
        }
    }
    table.print();
    let (trace_ns_per_site, trace_worst_frac) = assert_trace_overhead(&all_rows);
    println!(
        "trace overhead gate: {trace_ns_per_site:.1} ns/site disabled, worst {:.4}% of wall (<= 1%)",
        trace_worst_frac * 100.0
    );
    let (telemetry_ns_per_site, telemetry_worst_frac) = assert_telemetry_overhead(&all_rows);
    println!(
        "telemetry overhead gate: {telemetry_ns_per_site:.1} ns/site disabled, \
         worst {:.4}% of wall (<= 1%)",
        telemetry_worst_frac * 100.0
    );

    let artifact = Value::obj(vec![
        ("bench", Value::Str("dispatch_microbench".to_string())),
        ("draft", Value::Str(draft_name)),
        ("gamma", Value::Num(gamma as f64)),
        ("max_new", Value::Num(max_new as f64)),
        ("batched_available", Value::Bool(batched_available)),
        ("trace_ns_per_site_disabled", Value::Num(trace_ns_per_site)),
        ("trace_overhead_worst_frac", Value::Num(trace_worst_frac)),
        ("telemetry_ns_per_site_disabled", Value::Num(telemetry_ns_per_site)),
        ("telemetry_overhead_worst_frac", Value::Num(telemetry_worst_frac)),
        (
            "batch_size",
            decoder.draft.batch_size().map(|b| Value::Num(b as f64)).unwrap_or(Value::Null),
        ),
        ("rows", Value::Arr(rows_json)),
    ]);
    write_bench_json(args.str("out"), &artifact)?;
    println!("wrote {}", args.str("out"));
    Ok(())
}

//! Poisson-arrival HTTP load generator for the `specd serve` subsystem.
//!
//! Fires open-loop Poisson arrivals (like the trace replay in
//! `serve_benchmark`, but over real TCP against a running server) from a
//! pool of client threads, then reports status counts, latency/TTFT
//! percentiles and token throughput.
//!
//! ```sh
//! # terminal 1
//! cargo run --release -- serve --addr 127.0.0.1:8080 --max-slots 4
//! # terminal 2
//! cargo run --release --example http_load -- \
//!     --addr 127.0.0.1:8080 --requests 64 --rate 4.0 --stream
//! ```
//!
//! After the run the server's `/metrics` is scraped and the scheduler
//! families (slot-pool occupancy, per-phase timing) are echoed, so one
//! invocation captures both client- and server-side views. The numbers
//! from this binary are recorded in EXPERIMENTS.md.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use specd::benchkit::Stats;
use specd::cli::Args;
use specd::http;
use specd::json::{ObjWriter, Value};
use specd::rng::Pcg64;
use specd::workload::{parse_len_mix, stretch_prompt};

#[derive(Debug)]
struct Outcome {
    code: u16,
    /// Client-observed end-to-end latency, seconds.
    latency: f64,
    /// Client-observed time to first streamed chunk (stream mode only).
    ttft: Option<f64>,
    tokens: usize,
    /// Backpressure retries burned before this outcome (429/503 with the
    /// server's `Retry-After` hint honored).
    retries: usize,
}

/// Give up on a request after this many backpressure retries; the final
/// 429/503 is then reported as the request's outcome.
const RETRY_CAP: usize = 5;

/// One request with well-behaved backpressure handling: on 429/503 sleep
/// for the server's `Retry-After` hint scaled by uniform jitter in
/// [0.5, 1.0] (so a burst of rejected clients spreads out instead of
/// stampeding back in lockstep when the hint expires), then re-fire.
fn fire_with_retry(addr: &str, body: &str, stream: bool, jrng: &mut Pcg64) -> Option<Outcome> {
    let mut retries = 0usize;
    loop {
        let (out, retry_after) = fire(addr, body, stream)?;
        if !matches!(out.code, 429 | 503) || retries >= RETRY_CAP {
            return Some(Outcome { retries, ..out });
        }
        retries += 1;
        let hint = retry_after.unwrap_or(1.0).clamp(0.05, 60.0);
        let wait = hint * (0.5 + 0.5 * jrng.next_f64());
        std::thread::sleep(Duration::from_secs_f64(wait));
    }
}

fn main() -> specd::Result<()> {
    let args = Args::new("http_load", "Poisson HTTP load generator for specd serve")
        .opt("addr", "127.0.0.1:8080", "server address")
        .opt("requests", "64", "total requests")
        .opt("rate", "4.0", "Poisson arrival rate, req/s")
        .opt("clients", "16", "client threads")
        .opt("max-new", "32", "max new tokens per request")
        .opt("tokens", "1,3,5,6,7,4", "prompt token ids (comma-separated)")
        .opt("prompt", "", "prompt text (overrides --tokens; server-side encode)")
        .opt("len-mix", "",
             "len:weight prompt-length mixture cycled over --tokens \
              (e.g. 8:0.7,96:0.3; '' = one shared prompt)")
        .opt("task", "dolly", "sampling regime task name")
        .opt("timeout-ms", "0", "per-request deadline sent to the server (0 = none)")
        .opt("seed", "0", "arrival-schedule seed")
        .flag("stream", "use ?stream=1 chunked streaming")
        .flag("watch-stats",
              "follow the server's SSE telemetry stream (/debug/stats?stream=1) and \
               print one accept-rate/tokens-per-sec line per sealed window; with \
               --requests 0 this is a pure watch session (no load fired)")
        .parse()?;

    let addr = args.str("addr").to_string();
    let n = args.usize("requests")?;
    let rate = args.f64("rate")?;
    let stream = args.flag("stream");
    let max_new = args.usize("max-new")?;

    // Request bodies. Default: ONE body shared by every request (seed
    // varies server-side by id). With --len-mix: one body per request,
    // its token prompt stretched to a length drawn from the mixture, so
    // the server's admission path sees a realistic short-chat vs
    // long-document arrival pattern instead of uniform prompts.
    let timeout_ms = args.ms_opt("timeout-ms")?.map(|d| d.as_millis() as f64);
    let build_body = |toks: Option<&[u32]>| -> String {
        let mut b = ObjWriter::new()
            .num("max_new", max_new as f64)
            .str("task", args.str("task"));
        b = match toks {
            Some(t) => b.u32_arr("tokens", t),
            None => b.str("prompt", args.str("prompt")),
        };
        if let Some(ms) = timeout_ms {
            b = b.num("timeout_ms", ms);
        }
        b.finish()
    };
    let base_toks: Option<Vec<u32>> = if args.str("prompt").is_empty() {
        Some(
            args.list("tokens")
                .iter()
                .map(|t| {
                    t.parse::<u32>().map_err(|_| specd::Error::Cli(format!("bad token '{t}'")))
                })
                .collect::<specd::Result<_>>()?,
        )
    } else {
        None
    };
    let bodies: Arc<Vec<String>> = Arc::new(if args.str("len-mix").is_empty() {
        vec![build_body(base_toks.as_deref())]
    } else {
        let Some(toks) = base_toks.as_deref() else {
            return Err(specd::Error::Cli(
                "--len-mix needs client-side --tokens prompts (text prompts are \
                 encoded server-side and cannot be stretched here)"
                    .into(),
            ));
        };
        let mix = parse_len_mix(args.str("len-mix"))?;
        let weights: Vec<f32> = mix.iter().map(|(_, w)| *w as f32).collect();
        let mut lrng = Pcg64::with_stream(args.u64("seed")?, 0x11e7);
        (0..n)
            .map(|_| {
                let target = mix[lrng.categorical(&weights)].0;
                build_body(Some(&stretch_prompt(toks, target)))
            })
            .collect()
    });

    // Poisson schedule: exponential inter-arrival offsets from t0.
    let mut rng = Pcg64::with_stream(args.u64("seed")?, 0x10ad);
    let mut t = 0.0f64;
    let schedule: Arc<Vec<Duration>> = Arc::new(
        (0..n)
            .map(|_| {
                t += -(1.0 - rng.next_f64()).ln() / rate;
                Duration::from_secs_f64(t)
            })
            .collect(),
    );

    println!(
        "firing {n} requests at {rate:.1} req/s over {:?} ({} clients, stream={stream})",
        schedule.last().copied().unwrap_or_default(),
        args.usize("clients")?
    );

    // Optional live telemetry view: one line per sealed snapshot window,
    // printed while the load runs. The SSE stream never ends on its own,
    // so in mixed mode the thread dies with the process at exit; with
    // --requests 0 we join it instead (watch until the server goes away).
    let watcher = args.flag("watch-stats").then(|| {
        let addr = addr.clone();
        std::thread::spawn(move || watch_stats(&addr))
    });

    let cursor = Arc::new(AtomicUsize::new(0));
    let outcomes: Arc<Mutex<Vec<Outcome>>> = Arc::new(Mutex::new(Vec::with_capacity(n)));
    let t0 = Instant::now();
    let mut workers = Vec::new();
    let seed = args.u64("seed")?;
    for widx in 0..args.usize("clients")?.max(1) {
        let (addr, bodies, schedule, cursor, outcomes) =
            (addr.clone(), bodies.clone(), schedule.clone(), cursor.clone(), outcomes.clone());
        // Per-worker jitter stream for backoff so retrying clients
        // desynchronize even when rejected at the same instant.
        let mut jrng = Pcg64::with_stream(seed, 0xbac0 + widx as u64);
        workers.push(std::thread::spawn(move || loop {
            let i = cursor.fetch_add(1, Ordering::SeqCst);
            if i >= schedule.len() {
                break;
            }
            if let Some(wait) = schedule[i].checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let out = fire_with_retry(&addr, &bodies[i % bodies.len()], stream, &mut jrng)
                .unwrap_or(Outcome { code: 0, latency: 0.0, ttft: None, tokens: 0, retries: 0 });
            outcomes.lock().unwrap().push(out);
        }));
    }
    for w in workers {
        let _ = w.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- report -----------------------------------------------------------
    let outcomes = outcomes.lock().unwrap();
    let mut by_code: std::collections::BTreeMap<u16, usize> = Default::default();
    for o in outcomes.iter() {
        *by_code.entry(o.code).or_default() += 1;
    }
    let codes: Vec<String> = by_code.iter().map(|(c, k)| format!("{c}:{k}")).collect();
    let ok: Vec<&Outcome> = outcomes.iter().filter(|o| o.code == 200).collect();
    let total_tokens: usize = ok.iter().map(|o| o.tokens).sum();
    println!("status: [{}]  wall={wall:.2}s", codes.join(" "));
    let total_retries: usize = outcomes.iter().map(|o| o.retries).sum();
    if total_retries > 0 {
        let retried = outcomes.iter().filter(|o| o.retries > 0).count();
        println!(
            "backpressure: {total_retries} retries across {retried} requests \
             (Retry-After honored with jitter, cap {RETRY_CAP})"
        );
    }
    println!(
        "throughput: {:.1} tok/s, {:.2} ok-req/s",
        total_tokens as f64 / wall,
        ok.len() as f64 / wall
    );
    if !ok.is_empty() {
        let lat = Stats::from(ok.iter().map(|o| o.latency).collect());
        println!(
            "latency: p50={:.0}ms p90={:.0}ms p99={:.0}ms max={:.0}ms",
            lat.p50 * 1e3,
            lat.p90 * 1e3,
            lat.p99 * 1e3,
            lat.max * 1e3
        );
        let ttfts: Vec<f64> = ok.iter().filter_map(|o| o.ttft).collect();
        if !ttfts.is_empty() {
            let tt = Stats::from(ttfts);
            println!("ttft (streamed): p50={:.0}ms p90={:.0}ms", tt.p50 * 1e3, tt.p90 * 1e3);
        }
    }

    // Server-side view: scheduler pool occupancy + per-phase timing.
    match scrape_metrics(&addr) {
        Some(text) => {
            println!("server /metrics (scheduler + phase families):");
            // Only the `specd_sched_*` families are live scheduler-side
            // state; the coordinator's own aggregate families surface at
            // shutdown, not on the serving endpoint.
            for line in
                text.lines().filter(|l| !l.starts_with('#') && l.starts_with("specd_sched_"))
            {
                println!("  {line}");
            }
        }
        None => println!("server /metrics scrape failed (server gone?)"),
    }
    if let Some(w) = watcher {
        if n == 0 {
            let _ = w.join();
        }
    }
    Ok(())
}

/// Follow `/debug/stats?stream=1` (SSE over chunked transfer) and print a
/// compact per-window line per `data:` event. Returns when the server
/// closes the stream or the transport fails.
fn watch_stats(addr: &str) {
    let Ok(mut conn) = TcpStream::connect(addr) else {
        eprintln!("watch-stats: connect {addr} failed");
        return;
    };
    let ok = write!(
        conn,
        "GET /debug/stats?stream=1 HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n"
    )
    .and_then(|_| conn.flush());
    if ok.is_err() {
        eprintln!("watch-stats: request failed");
        return;
    }
    let mut rd = BufReader::new(conn);
    let head = match http::read_response_head(&mut rd) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("watch-stats: bad response: {e}");
            return;
        }
    };
    if head.code != 200 {
        eprintln!(
            "watch-stats: HTTP {} (server needs --debug-endpoints and telemetry on)",
            head.code
        );
        return;
    }
    let mut chunks = http::ChunkedReader::new(&mut rd);
    let mut buf = String::new();
    while let Ok(Some(chunk)) = chunks.next_chunk() {
        buf.push_str(&String::from_utf8_lossy(&chunk));
        // SSE events are \n\n-delimited; keep any trailing partial event.
        while let Some(end) = buf.find("\n\n") {
            let event: String = buf.drain(..end + 2).collect();
            let Some(payload) = event.lines().find_map(|l| l.strip_prefix("data: ")) else {
                continue; // keepalive comment
            };
            let Ok(v) = Value::parse(payload.trim()) else { continue };
            let f = |k: &str| v.get(k).as_f64().unwrap_or(0.0);
            let drift = v.get("health").get("drift_active").as_bool().unwrap_or(false);
            println!(
                "stats: seq={} accept={:.1}% depth={:.2} tok/s={:.1} disp/s={:.1} \
                 queue={} drift={}",
                f("seq") as u64,
                f("accept_rate") * 100.0,
                f("mean_accept_depth"),
                f("tokens_per_sec"),
                f("dispatches_per_sec"),
                f("queue_depth") as u64,
                if drift { "ACTIVE" } else { "quiet" },
            );
        }
    }
}

/// GET /metrics on a fresh connection; None on any failure.
fn scrape_metrics(addr: &str) -> Option<String> {
    let mut conn = TcpStream::connect(addr).ok()?;
    conn.set_read_timeout(Some(Duration::from_secs(10))).ok();
    write!(conn, "GET /metrics HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n").ok()?;
    conn.flush().ok()?;
    let mut rd = BufReader::new(conn);
    let resp = http::read_response(&mut rd).ok()?;
    (resp.code == 200).then(|| resp.body_str().to_string())
}

/// One request on a fresh connection; returns None on transport failure.
/// The second element is the server's `Retry-After` hint in seconds, when
/// the response carried one.
fn fire(addr: &str, body: &str, stream: bool) -> Option<(Outcome, Option<f64>)> {
    let start = Instant::now();
    let mut conn = TcpStream::connect(addr).ok()?;
    conn.set_nodelay(true).ok();
    conn.set_read_timeout(Some(Duration::from_secs(300))).ok();
    let target = if stream { "/v1/generate?stream=1" } else { "/v1/generate" };
    write!(
        conn,
        "POST {target} HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n{body}",
        body.len()
    )
    .ok()?;
    conn.flush().ok()?;

    let mut rd = BufReader::new(conn);
    let head = http::read_response_head(&mut rd).ok()?;
    let retry_after = head.header("retry-after").and_then(|v| v.trim().parse::<f64>().ok());
    if head.chunked() {
        // Streamed: count tokens per event, timestamp the first chunk.
        let mut ttft = None;
        let mut tokens = 0usize;
        let mut chunks = http::ChunkedReader::new(&mut rd);
        while let Ok(Some(chunk)) = chunks.next_chunk() {
            ttft.get_or_insert_with(|| start.elapsed().as_secs_f64());
            let text = String::from_utf8_lossy(&chunk);
            for event in text.split("\n\n").filter(|e| !e.is_empty()) {
                let Some(payload) = event.strip_prefix("data: ") else { continue };
                if let Ok(v) = Value::parse(payload.trim()) {
                    if v.get("done").as_bool() != Some(true) {
                        tokens += v.get("tokens").as_arr().map(|a| a.len()).unwrap_or(0);
                    }
                }
            }
        }
        let out = Outcome {
            code: head.code,
            latency: start.elapsed().as_secs_f64(),
            ttft,
            tokens,
            retries: 0,
        };
        Some((out, retry_after))
    } else {
        let mut head = head;
        http::read_body(&mut rd, &mut head).ok()?;
        let tokens = Value::parse(&head.body_str())
            .ok()
            .and_then(|v| v.get("tokens").as_arr().map(|a| a.len()))
            .unwrap_or(0);
        let out = Outcome {
            code: head.code,
            latency: start.elapsed().as_secs_f64(),
            ttft: None,
            tokens,
            retries: 0,
        };
        Some((out, retry_after))
    }
}

//! End-to-end serving driver (the repository's headline validation run):
//! replays a Poisson-arrival trace of chat requests through the
//! coordinator with speculative decoding, then replays the identical trace
//! with autoregressive decoding, and reports latency/throughput for both.
//!
//! ```sh
//! cargo run --release --example serve_benchmark -- \
//!     --requests 32 --rate 2.0 --max-slots 4 --gamma 3
//! ```
//!
//! The numbers from this binary are recorded in EXPERIMENTS.md.

use std::sync::Arc;

use specd::artifacts::Manifest;
use specd::baseline::ArDecoder;
use specd::cli::Args;
use specd::config::{RunConfig, SamplingConfig};
use specd::coordinator::{Coordinator, Request, Response};
use specd::exec;
use specd::metrics::ServeMetrics;
use specd::rng::Pcg64;
use specd::runtime::Runtime;
use specd::spec::SpecDecoder;
use specd::workload::{build_trace, EvalSuite, TraceConfig, TraceRequest};

fn main() -> specd::Result<()> {
    let args = Args::new("serve_benchmark", "trace-replay serving benchmark")
        .opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("draft", "", "draft model (default: best tvdpp checkpoint)")
        .opt("gamma", "3", "speculation depth")
        .opt("requests", "32", "number of requests")
        .opt("rate", "2.0", "Poisson arrival rate, req/s")
        .opt("max-slots", "4", "KV slot pool size (resident sequences)")
        .alias("max-batch", "max-slots")
        .opt("max-new", "32", "max new tokens per request")
        .opt("seed", "0", "trace seed")
        .opt("mix", "chat", "workload mix: chat (dolly-only) | paper (dolly/cnndm/xsum)")
        .opt("len-mix", "", "len:weight prompt-length mixture (e.g. 8:0.7,96:0.3; '' = natural)")
        .opt("prefill-budget", "0",
             "admission prefill tokens per scheduler iteration (0 = unbounded)")
        .opt("bench-json", "", "write machine-readable metrics to this path (BENCH_serve.json)")
        .flag("skip-baseline", "skip the autoregressive replay")
        .parse()?;

    let manifest = Manifest::load(args.str("artifacts"))?;
    let rt = Arc::new(Runtime::new()?);
    let draft_arch = rt.load_arch(&manifest, "draft")?;
    let target_arch = rt.load_arch(&manifest, "target")?;
    let target = rt.load_model(&manifest, &target_arch, "target")?;
    let draft_name = if args.str("draft").is_empty() {
        manifest
            .draft_models()
            .into_iter()
            .filter(|n| n.contains("tvdpp")).max()
            .unwrap_or_else(|| "draft_base".to_string())
    } else {
        args.str("draft").to_string()
    };
    let draft = rt.load_model(&manifest, &draft_arch, &draft_name)?;
    let suite = EvalSuite::load(&manifest.root.join("eval_prompts.json"))?;

    // "chat" = the paper's motivating deployment (open-ended dialogue, the
    // distribution the draft is aligned to); "paper" = the Figure 1 task mix.
    let mix = match args.str("mix") {
        "paper" => TraceConfig::default().mix,
        _ => vec![("dolly".to_string(), 1.0)],
    };
    let trace_cfg = TraceConfig {
        rate: args.f64("rate")?,
        n_requests: args.usize("requests")?,
        max_new: args.usize("max-new")?,
        seed: args.u64("seed")?,
        mix,
        prompt_len_mix: if args.str("len-mix").is_empty() {
            Vec::new()
        } else {
            specd::workload::parse_len_mix(args.str("len-mix"))?
        },
    };
    let trace = build_trace(&suite, &trace_cfg)?;
    println!(
        "trace: {} requests @ {:.1} req/s over {:?} (draft {}, gamma {})",
        trace.len(),
        trace_cfg.rate,
        trace.last().map(|r| r.arrival).unwrap_or_default(),
        draft_name,
        args.usize("gamma")?
    );

    // --- speculative serving run -----------------------------------------
    let gamma = args.usize("gamma")?;
    let decoder = SpecDecoder::new(&draft, &target, gamma)?;
    let cfg = RunConfig {
        gamma,
        max_slots: args.usize("max-slots")?,
        max_new_tokens: trace_cfg.max_new,
        prefill_budget: args.usize("prefill-budget")?,
        ..RunConfig::default()
    };
    let coord = Coordinator::new(decoder, cfg)?;
    let sd = replay(&coord, &trace)?;
    println!("\n== speculative decoding ==\n{}", sd.report());

    // --- autoregressive replay (sequential engine, same prompts) ---------
    let mut ar_metrics = None;
    if !args.flag("skip-baseline") {
        let ar = ar_replay(&target, &trace)?;
        println!("\n== autoregressive baseline ==\n{}", ar.report());
        let ratio = sd.throughput_tok_s() / ar.throughput_tok_s().max(1e-9);
        let p50 = |m: &ServeMetrics| m.latency_stats().map(|s| s.p50).unwrap_or(0.0);
        println!(
            "\nSD/AR: throughput x{ratio:.2}, p50 latency {:.0}ms -> {:.0}ms",
            p50(&ar) * 1e3,
            p50(&sd) * 1e3
        );
        ar_metrics = Some(ar);
    }
    if !args.str("bench-json").is_empty() {
        let row = |m: &ServeMetrics| {
            specd::json::Value::obj(vec![
                ("requests", specd::json::Value::Num(m.total_requests as f64)),
                ("tokens", specd::json::Value::Num(m.total_new_tokens as f64)),
                ("tokens_per_sec", specd::json::Value::Num(m.throughput_tok_s())),
                ("dispatches", specd::json::Value::Num(m.dispatches as f64)),
                ("batch_occupancy", specd::json::Value::Num(m.batch_occupancy())),
                ("block_efficiency", specd::json::Value::Num(m.spec.block_efficiency())),
            ])
        };
        let mut fields = vec![
            ("bench", specd::json::Value::Str("serve_benchmark".to_string())),
            ("gamma", specd::json::Value::Num(gamma as f64)),
            ("sd", row(&sd)),
        ];
        if let Some(ar) = &ar_metrics {
            fields.push(("ar", row(ar)));
        }
        let v = specd::json::Value::obj(fields);
        specd::benchkit::write_bench_json(args.str("bench-json"), &v)?;
        println!("wrote {}", args.str("bench-json"));
    }
    Ok(())
}

/// Feed the trace through the coordinator with real arrival timing.
fn replay(coord: &Coordinator, trace: &[TraceRequest]) -> specd::Result<ServeMetrics> {
    let (req_tx, req_rx) = exec::bounded::<Request>(64);
    let (resp_tx, resp_rx) = exec::bounded::<Response>(256);
    let trace_owned: Vec<TraceRequest> = trace.to_vec();
    let client = std::thread::spawn(move || {
        let t0 = std::time::Instant::now();
        for (i, r) in trace_owned.into_iter().enumerate() {
            if let Some(wait) = r.arrival.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let _ = req_tx.send(Request::new(
                i as u64,
                r.prompt,
                r.max_new,
                SamplingConfig::for_task(&r.task, i as u64),
            ));
        }
    });
    let metrics = coord.serve(req_rx, resp_tx)?;
    client.join().expect("client thread");
    let mut failures = 0;
    while let Some(r) = resp_rx.try_recv() {
        if r.error.is_some() {
            failures += 1;
        }
    }
    if failures > 0 {
        eprintln!("warning: {failures} failed requests");
    }
    Ok(metrics)
}

/// Sequential autoregressive replay (the no-draft deployment).
fn ar_replay(target: &specd::runtime::Model, trace: &[TraceRequest]) -> specd::Result<ServeMetrics> {
    let decoder = ArDecoder::new(target);
    let mut metrics = ServeMetrics::default();
    let wall0 = std::time::Instant::now();
    // Arrivals matter for latency: requests queue behind the sequential decoder.
    for (i, r) in trace.iter().enumerate() {
        if let Some(wait) = r.arrival.checked_sub(wall0.elapsed()) {
            std::thread::sleep(wait);
        }
        let cfg = SamplingConfig::for_task(&r.task, i as u64);
        let mut rng = Pcg64::with_stream(cfg.seed ^ i as u64, 0x5e0e);
        let (out, _stats, _rate) = decoder.generate(&r.prompt, r.max_new, &cfg, &mut rng)?;
        // Latency from the request's *scheduled arrival*: a sequential
        // decoder makes later requests queue behind earlier ones, and that
        // wait is part of the user-visible latency (the coordinator's
        // numbers include the analogous interleaving delay).
        let latency = (wall0.elapsed() - r.arrival).as_secs_f64().max(0.0);
        metrics.total_requests += 1;
        metrics.total_new_tokens += out.len();
        metrics.request_latency.push(latency);
        metrics.ttft.push(latency / out.len().max(1) as f64); // first AR token
    }
    metrics.wall_seconds = wall0.elapsed().as_secs_f64();
    Ok(metrics)
}

//! Compare draft models fine-tuned with KLD vs TVD vs TVD++ (the paper's
//! central ablation) on one task, printing block efficiency, acceptance
//! rate and MBSU per loss — a fast, single-cell view of Figure 1.
//!
//! ```sh
//! cargo run --release --example compare_losses -- --task dolly --gamma 3
//! ```

use std::sync::Arc;

use specd::artifacts::Manifest;
use specd::benchkit::Table;
use specd::cli::Args;
use specd::eval::{eval_block_efficiency, EvalOptions};
use specd::runtime::Runtime;
use specd::workload::EvalSuite;

fn main() -> specd::Result<()> {
    let args = Args::new("compare_losses", "KLD vs TVD vs TVD++ draft comparison")
        .opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("task", "dolly", "task: dolly|xsum|cnndm|wmt")
        .opt("gamma", "3", "speculation depth")
        .opt("prompts", "12", "prompts per cell")
        .opt("max-new", "32", "max new tokens")
        .parse()?;

    let manifest = Manifest::load(args.str("artifacts"))?;
    let rt = Arc::new(Runtime::new()?);
    let draft_arch = rt.load_arch(&manifest, "draft")?;
    let target_arch = rt.load_arch(&manifest, "target")?;
    let target = rt.load_model(&manifest, &target_arch, "target")?;
    let suite = EvalSuite::load(&manifest.root.join("eval_prompts.json"))?;

    let opts = EvalOptions {
        n_prompts: args.usize("prompts")?,
        max_new: args.usize("max-new")?,
        seed: 0,
    };
    let task = args.str("task");
    let gamma = args.usize("gamma")?;

    // Base draft + the final checkpoint of each loss.
    let all = manifest.draft_models();
    let last_ckpt = |loss: &str| -> Option<String> {
        all.iter().filter(|n| n.contains(&format!("_{loss}_"))).max().cloned()
    };
    let mut candidates: Vec<(String, String)> =
        vec![("base (pretrain only)".to_string(), "draft_base".to_string())];
    for loss in ["kld", "tvd", "tvdpp"] {
        if let Some(name) = last_ckpt(loss) {
            candidates.push((loss.to_uppercase().replace("PP", "++"), name));
        }
    }

    println!("task={task} gamma={gamma} ({} prompts, max_new={})", opts.n_prompts, opts.max_new);
    let mut table = Table::new(&["loss", "model", "tau", "acceptance", "MBSU"]);
    for (label, model_name) in candidates {
        let draft = rt.load_model(&manifest, &draft_arch, &model_name)?;
        let cell = eval_block_efficiency(&draft, &target, &suite, task, gamma, &opts)?;
        table.row(&[
            label,
            model_name,
            format!("{:.3}", cell.tau),
            format!("{:.3}", cell.acceptance),
            format!("{:.3}", cell.mbsu),
        ]);
    }
    table.print();
    println!("\n(paper expectation: TVD++ >= TVD ~ KLD > base on in-distribution tasks;");
    println!(" on the OOD task `wmt`, base outperforms all fine-tuned drafts — Figure 3)");
    Ok(())
}

#!/usr/bin/env python3
"""Repo-facing entry point for specd-lint (see python/tools/specd_lint/).

Stdlib-only: runs in containers with no Rust toolchain and no pip
packages, which is exactly why it exists -- `scripts/check.sh` runs it
first, before anything that needs cargo.

    python3 scripts/lint_specd.py [--rules ...] [--dump-metrics]
"""

import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "python"))

from tools.specd_lint.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--root", REPO_ROOT] + sys.argv[1:]))

#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, lints, formatting.
#
#   scripts/check.sh            # everything
#   scripts/check.sh --no-fmt   # skip the formatting gate
#
# The integration tests that need compiled artifacts skip themselves when
# the bundle is absent (run `make artifacts` first for full coverage).
set -euo pipefail
cd "$(dirname "$0")/.."

run_fmt=1
[[ "${1:-}" == "--no-fmt" ]] && run_fmt=0

# Toolchain-independent invariant analysis first: it needs only python3,
# so a broken invariant fails the run before any compile time is spent.
echo "== specd-lint (static invariants, no toolchain needed) =="
python3 scripts/lint_specd.py

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== loom concurrency models =="
# Rebuilds the crate with exec's sync primitives aliased to loom's. The
# vendored stub (rust/vendor/loom) runs each model once as a concurrency
# smoke test; substituting the real crate turns the same models into
# exhaustive interleaving checks (see the stub's docs).
RUSTFLAGS="--cfg loom" cargo test -q --test loom_models

echo "== chaos suite (seeded fault injection) =="
# Fault-domain gate: transient plans invisible (byte-identical greedy
# output, zero request errors), burst plans absorbed by lane salvage +
# breaker recovery. The io/exec-domain tests run artifact-free; the
# dispatch-domain sweeps self-skip without a model bundle.
cargo test -q --test chaos_integration

echo "== lifecycle suite (hot swap / rollback / supervision) =="
# Draft-lifecycle gate: mid-stream bundle swap byte-identical with zero
# drops, corrupt/incompatible candidates rejected with zero serving
# impact, breaker- and drift-triggered rollbacks, scheduler-panic
# recovery with exactly one terminal per request, and the restart-storm
# backstop. All tests self-skip without a model bundle.
cargo test -q --test lifecycle_integration

echo "== batched golden probes (artifact-gated) =="
if compgen -G "artifacts/hlo/*/verify.b*.hlo.txt" > /dev/null; then
    # Bundle exports batched [B, T] entry points: run the fused-dispatch
    # suites explicitly in release (numerics pins + the O(γ+2) dispatch
    # bound). These tests self-skip inside `cargo test` when gated, so
    # this stage is the one that actually exercises them.
    cargo test --release --test runtime_integration --test batched_integration
else
    echo "no batched artifact bundle; skipping (export with: cd python && python -m compile.aot)"
fi

echo "== tracing + telemetry suites =="
# Flight-recorder contract: ring wraparound, Chrome-trace export shape,
# request timelines, access-log lines (artifact-free), plus the python
# validators for the exported trace and telemetry snapshot-ring JSON.
# With an artifact bundle present, also produce a real replay trace and
# stats dump and validate both end to end.
cargo test -q --test trace_integration
if command -v python3 >/dev/null 2>&1 && python3 -c "import pytest" 2>/dev/null; then
    if [[ -f artifacts/manifest.json ]]; then
        cargo run --release --quiet -- replay --artifacts artifacts \
            --requests 4 --max-new 8 --trace-out trace.json \
            --telemetry-window 0.05 --stats-out stats.json
        (cd python && SPECD_TRACE_JSON="$PWD/../trace.json" \
            SPECD_STATS_JSON="$PWD/../stats.json" \
            python3 -m pytest tests/test_trace_export.py tests/test_stats_stream.py \
                tests/test_specd_lint.py -q)
    else
        (cd python && python3 -m pytest tests/test_trace_export.py \
            tests/test_stats_stream.py tests/test_specd_lint.py -q)
    fi
else
    echo "pytest unavailable; skipping python trace-export/stats/lint validation"
fi

echo "== cargo clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy component not installed; skipping (install with: rustup component add clippy)"
fi

echo "== cargo clippy pedantic subset (advisory) =="
# Thresholds live in clippy.toml. Advisory by design: findings print but
# never fail the run — the hard gate above stays `-D warnings` on the
# default lint set.
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- \
        -W clippy::pedantic \
        -A clippy::missing-errors-doc \
        -A clippy::missing-panics-doc \
        -A clippy::module-name-repetitions \
        -A clippy::must-use-candidate \
        -A clippy::cast-precision-loss \
        -A clippy::cast-possible-truncation \
        -A clippy::cast-sign-loss \
        || echo "pedantic findings above are advisory (not a gate)"
fi

if [[ "$run_fmt" == 1 ]]; then
    echo "== cargo fmt --check =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "rustfmt component not installed; skipping (install with: rustup component add rustfmt)"
    fi
fi

echo "tier-1: OK"

#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, lints, formatting.
#
#   scripts/check.sh            # everything
#   scripts/check.sh --no-fmt   # skip the formatting gate
#
# The integration tests that need compiled artifacts skip themselves when
# the bundle is absent (run `make artifacts` first for full coverage).
set -euo pipefail
cd "$(dirname "$0")/.."

run_fmt=1
[[ "${1:-}" == "--no-fmt" ]] && run_fmt=0

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== batched golden probes (artifact-gated) =="
if compgen -G "artifacts/hlo/*/verify.b*.hlo.txt" > /dev/null; then
    # Bundle exports batched [B, T] entry points: run the fused-dispatch
    # suites explicitly in release (numerics pins + the O(γ+2) dispatch
    # bound). These tests self-skip inside `cargo test` when gated, so
    # this stage is the one that actually exercises them.
    cargo test --release --test runtime_integration --test batched_integration
else
    echo "no batched artifact bundle; skipping (export with: cd python && python -m compile.aot)"
fi

echo "== cargo clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy component not installed; skipping (install with: rustup component add clippy)"
fi

if [[ "$run_fmt" == 1 ]]; then
    echo "== cargo fmt --check =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "rustfmt component not installed; skipping (install with: rustup component add rustfmt)"
    fi
fi

echo "tier-1: OK"

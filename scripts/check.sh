#!/usr/bin/env bash
# Tier-1 verification in one command: build, tests, lints, formatting.
#
#   scripts/check.sh            # everything
#   scripts/check.sh --no-fmt   # skip the formatting gate
#
# The integration tests that need compiled artifacts skip themselves when
# the bundle is absent (run `make artifacts` first for full coverage).
set -euo pipefail
cd "$(dirname "$0")/.."

run_fmt=1
[[ "${1:-}" == "--no-fmt" ]] && run_fmt=0

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy component not installed; skipping (install with: rustup component add clippy)"
fi

if [[ "$run_fmt" == 1 ]]; then
    echo "== cargo fmt --check =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --check
    else
        echo "rustfmt component not installed; skipping (install with: rustup component add rustfmt)"
    fi
fi

echo "tier-1: OK"

#!/usr/bin/env python3
"""Diff two BENCH_*.json perf artifacts and gate on regressions.

Usage:
    scripts/bench_compare.py BASELINE.json CANDIDATE.json \
        [--max-regress 0.10] [--metric tokens_per_sec=0.05] [--report out.md]

Rows are matched by their identity keys (mode, lanes, budget, ...); every
shared numeric metric with a known direction is compared as a fractional
delta against the baseline. A metric regresses when it moves in the bad
direction by more than the threshold (default --max-regress, overridable
per metric with repeated --metric NAME=FRAC).

Exit codes: 0 all metrics within thresholds, 1 at least one regression,
2 usage / unreadable artifact. New or vanished rows are reported but are
not failures (lane sweeps legitimately change between PRs).
"""

import argparse
import json
import sys

# Keys that identify a row within an artifact (whichever subset is present).
ID_KEYS = ("bench", "mode", "lanes", "budget", "prefill_budget", "batch", "config", "name")

HIGHER_BETTER = {
    "tokens_per_sec", "batch_occupancy", "accept_rate", "block_efficiency",
    "mean_accept_depth", "requests_per_sec",
}
LOWER_BETTER = {
    "dispatches_per_block", "dispatches_per_step", "wall_seconds",
    "ttft_p50", "ttft_p90", "ttft_p99", "itl_p50", "itl_p90",
    "latency_p50", "latency_p90", "latency_p99",
    "trace_ns_per_site_disabled", "trace_overhead_worst_frac",
    "telemetry_ns_per_site_disabled", "telemetry_overhead_worst_frac",
}


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_compare: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def row_id(row):
    return tuple((k, row[k]) for k in ID_KEYS if k in row)


def fmt_id(rid):
    return " ".join(f"{k}={v}" for k, v in rid) or "(top-level)"


def numeric_metrics(obj):
    out = {}
    for k, v in obj.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        if k in HIGHER_BETTER or k in LOWER_BETTER:
            out[k] = float(v)
    return out


def compare_metrics(rid, base, cand, threshold_for, results):
    bm, cm = numeric_metrics(base), numeric_metrics(cand)
    for name in sorted(bm.keys() & cm.keys()):
        b, c = bm[name], cm[name]
        if abs(b) < 1e-12:
            continue  # no meaningful baseline to regress against
        frac = (c - b) / abs(b)
        thr = threshold_for(name)
        if name in HIGHER_BETTER:
            bad = frac < -thr
        else:
            bad = frac > thr
        results.append({
            "row": fmt_id(rid), "metric": name, "base": b, "cand": c,
            "delta_frac": frac, "threshold": thr,
            "status": "REGRESSION" if bad else "ok",
        })


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="default allowed bad-direction fractional move (0.10 = 10%%)")
    ap.add_argument("--metric", action="append", default=[], metavar="NAME=FRAC",
                    help="per-metric threshold override, repeatable")
    ap.add_argument("--report", default="", help="also write a markdown report here")
    args = ap.parse_args()

    overrides = {}
    for spec in args.metric:
        name, _, frac = spec.partition("=")
        if not frac:
            ap.error(f"--metric wants NAME=FRAC, got {spec!r}")
        overrides[name] = float(frac)

    def threshold_for(name):
        return overrides.get(name, args.max_regress)

    base, cand = load(args.baseline), load(args.candidate)
    results, notes = [], []

    # Top-level scalars (overhead gates etc.) compare like a row of their own.
    compare_metrics((), base, cand, threshold_for, results)

    base_rows = {row_id(r): r for r in base.get("rows", []) if isinstance(r, dict)}
    cand_rows = {row_id(r): r for r in cand.get("rows", []) if isinstance(r, dict)}
    for rid in sorted(base_rows.keys() | cand_rows.keys()):
        if rid not in cand_rows:
            notes.append(f"row vanished from candidate: {fmt_id(rid)}")
        elif rid not in base_rows:
            notes.append(f"new row (no baseline): {fmt_id(rid)}")
        else:
            compare_metrics(rid, base_rows[rid], cand_rows[rid], threshold_for, results)

    regressions = [r for r in results if r["status"] == "REGRESSION"]

    lines = [
        f"# bench compare: {args.candidate} vs baseline {args.baseline}",
        "",
        f"{len(results)} metric comparisons, {len(regressions)} regression(s), "
        f"default threshold {args.max_regress:.0%}",
        "",
        "| row | metric | baseline | candidate | delta | status |",
        "|---|---|---:|---:|---:|---|",
    ]
    for r in sorted(results, key=lambda r: (r["status"] != "REGRESSION", r["row"], r["metric"])):
        lines.append(
            f"| {r['row']} | {r['metric']} | {r['base']:.4g} | {r['cand']:.4g} "
            f"| {r['delta_frac']:+.1%} | {r['status']} |"
        )
    for n in notes:
        lines.append(f"\n- note: {n}")
    report = "\n".join(lines) + "\n"

    if args.report:
        with open(args.report, "w") as f:
            f.write(report)
    print(report, end="")

    if regressions:
        print(f"bench_compare: {len(regressions)} regression(s) beyond threshold", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# Perf artifacts in one command (the BENCH_* trajectory files):
#
#   scripts/bench.sh                 # dispatch microbench + serve benchmark
#   ARTIFACTS=path scripts/bench.sh  # non-default bundle location
#
# Produces:
#   BENCH_pr4.json    per-lane vs fused-batched dispatch microbench
#                     (tokens/s, dispatches/block, batch occupancy)
#   BENCH_pr5.json    admission microbench: wave vs per-sequence dispatch
#                     bills + TTFT percentiles vs --prefill-budget
#   BENCH_serve.json  trace-replay serving benchmark (SD vs AR)
#
# Both need a compiled artifact bundle; without one this script prints a
# note and exits 0 (CI runs it opportunistically).
set -euo pipefail
cd "$(dirname "$0")/.."

ART="${ARTIFACTS:-artifacts}"
if [[ ! -f "$ART/manifest.json" ]]; then
    echo "no artifact bundle at $ART (run \`make artifacts\` / python -m compile.aot); nothing to bench"
    exit 0
fi

echo "== dispatch microbench (BENCH_pr4.json) =="
cargo run --release --example dispatch_microbench -- \
    --artifacts "$ART" --lanes 1,4,8 --out BENCH_pr4.json

echo "== admission microbench (BENCH_pr5.json) =="
cargo run --release --example admission_microbench -- \
    --artifacts "$ART" --lanes 1,4,8 --budgets 0,32,128 --out BENCH_pr5.json

echo "== serve benchmark (BENCH_serve.json) =="
cargo run --release --example serve_benchmark -- \
    --artifacts "$ART" --bench-json BENCH_serve.json "$@"

echo "bench artifacts: BENCH_pr4.json BENCH_pr5.json BENCH_serve.json"

# Regression gate: when a baseline bundle is available (previous run's
# artifacts, e.g. restored by CI into bench_baseline/), diff against it.
BASE="${BENCH_BASELINE_DIR:-bench_baseline}"
if [[ -d "$BASE" ]]; then
    status=0
    for f in BENCH_pr4.json BENCH_pr5.json BENCH_serve.json; do
        if [[ -f "$BASE/$f" && -f "$f" ]]; then
            echo "== bench compare: $f vs $BASE/$f =="
            python3 scripts/bench_compare.py "$BASE/$f" "$f" \
                --report "BENCH_compare_${f%.json}.md" || status=1
        fi
    done
    exit $status
else
    echo "no baseline dir at $BASE; skipping bench_compare"
fi

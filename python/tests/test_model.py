"""L2 model invariants: cached vs batched forward parity, pallas vs ref
parity, KV-cache incremental consistency, rollback safety, param counting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import DRAFT_CONFIG, TARGET_CONFIG, ModelConfig

jax.config.update("jax_platform_name", "cpu")

TINY = ModelConfig(name="tiny", vocab_size=64, n_layers=2, n_heads=2, hidden=16,
                   intermediate=32, max_seq=64)


@pytest.fixture(scope="module")
def tiny_params():
    return model.init_params(TINY, seed=0)


def tokens(rng, n, cfg=TINY):
    return jnp.asarray(rng.integers(5, cfg.vocab_size, n).astype(np.int32))


def test_param_count_matches_config():
    for cfg in (TINY, DRAFT_CONFIG, TARGET_CONFIG):
        params = model.init_params(cfg, seed=1)
        assert model.count_params(params) == cfg.param_count()


def test_param_names_sorted_and_complete(tiny_params):
    names = model.param_names(TINY)
    assert names == sorted(names)
    assert set(names) == set(tiny_params.keys())
    for n in names:
        assert tiny_params[n].shape == model.param_shape(TINY, n)


def test_draft_target_ratio_near_paper():
    c = DRAFT_CONFIG.param_count() / TARGET_CONFIG.param_count()
    # Paper: 1.64%. Ours: within [1%, 3%].
    assert 0.01 < c < 0.03, c


def test_cached_equals_train_forward(tiny_params):
    rng = np.random.default_rng(0)
    toks = tokens(rng, 12)
    logits_train = model.forward_train(tiny_params, TINY, toks[None])[0]
    kv = model.init_kv(TINY)
    logits_cached, _ = model.forward_cached(
        tiny_params, TINY, toks, kv, jnp.asarray(0, jnp.int32), use_pallas=False
    )
    np.testing.assert_allclose(logits_cached, logits_train, rtol=2e-4, atol=2e-4)


def test_pallas_path_equals_ref_path(tiny_params):
    rng = np.random.default_rng(1)
    toks = tokens(rng, 8)
    kv = model.init_kv(TINY)
    pos = jnp.asarray(0, jnp.int32)
    lp, kvp = model.forward_cached(tiny_params, TINY, toks, kv, pos, use_pallas=True)
    lr, kvr = model.forward_cached(tiny_params, TINY, toks, kv, pos, use_pallas=False)
    np.testing.assert_allclose(lp, lr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(kvp, kvr, rtol=2e-4, atol=2e-4)


def test_incremental_decode_equals_full_prefill(tiny_params):
    """Prefill(a+b) == Prefill(a) then decode(b) token by token."""
    rng = np.random.default_rng(2)
    full = tokens(rng, 10)
    kv = model.init_kv(TINY)
    logits_full, _ = model.forward_cached(
        tiny_params, TINY, full, kv, jnp.asarray(0, jnp.int32), use_pallas=False
    )
    kv = model.init_kv(TINY)
    logits_inc, kv = model.forward_cached(
        tiny_params, TINY, full[:4], kv, jnp.asarray(0, jnp.int32), use_pallas=False
    )
    rows = [np.asarray(logits_inc)]
    for i in range(4, 10):
        li, kv = model.forward_cached(
            tiny_params, TINY, full[i : i + 1], kv, jnp.asarray(i, jnp.int32), use_pallas=False
        )
        rows.append(np.asarray(li))
    got = np.concatenate(rows, axis=0)
    np.testing.assert_allclose(got, logits_full, rtol=5e-4, atol=5e-4)


def test_rollback_by_position_is_safe(tiny_params):
    """Speculation writes rows then gets rejected: recomputing from the
    accepted length must give identical logits, stale rows untouched."""
    rng = np.random.default_rng(3)
    prefix = tokens(rng, 6)
    spec = tokens(rng, 3)  # speculative continuation, will be rejected
    corrected = tokens(rng, 1)

    kv = model.init_kv(TINY)
    _, kv = model.forward_cached(
        tiny_params, TINY, prefix, kv, jnp.asarray(0, jnp.int32), use_pallas=False
    )
    # Write speculation at 6..8, then "reject all" and feed the corrected
    # token at position 6 (overwrites row 6; rows 7,8 stay stale).
    _, kv_spec = model.forward_cached(
        tiny_params, TINY, spec, kv, jnp.asarray(6, jnp.int32), use_pallas=False
    )
    logits_after_rollback, _ = model.forward_cached(
        tiny_params, TINY, corrected, kv_spec, jnp.asarray(6, jnp.int32), use_pallas=False
    )
    # Ground truth: clean cache, same sequence.
    kv2 = model.init_kv(TINY)
    _, kv2 = model.forward_cached(
        tiny_params, TINY, prefix, kv2, jnp.asarray(0, jnp.int32), use_pallas=False
    )
    logits_clean, _ = model.forward_cached(
        tiny_params, TINY, corrected, kv2, jnp.asarray(6, jnp.int32), use_pallas=False
    )
    np.testing.assert_allclose(logits_after_rollback, logits_clean, rtol=5e-4, atol=5e-4)


def test_rope_position_dependence(tiny_params):
    """Same token at different positions must produce different logits
    (RoPE is actually applied)."""
    rng = np.random.default_rng(4)
    seq = tokens(rng, 5)
    kv = model.init_kv(TINY)
    _, kv = model.forward_cached(
        tiny_params, TINY, seq, kv, jnp.asarray(0, jnp.int32), use_pallas=False
    )
    tok = tokens(rng, 1)
    l5, _ = model.forward_cached(
        tiny_params, TINY, tok, kv, jnp.asarray(5, jnp.int32), use_pallas=False
    )
    # Re-use the same cache but place the token at position 3 (overwrite).
    l3, _ = model.forward_cached(
        tiny_params, TINY, tok, kv, jnp.asarray(3, jnp.int32), use_pallas=False
    )
    assert not np.allclose(np.asarray(l5), np.asarray(l3), atol=1e-5)


def test_rope_rotation_preserves_norm():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(7, 2, 16)).astype(np.float32))
    y = model.rope(x, jnp.arange(7), theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_init_deterministic():
    a = model.init_params(TINY, seed=7)
    b = model.init_params(TINY, seed=7)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    c = model.init_params(TINY, seed=8)
    assert any(not np.allclose(np.asarray(a[k]), np.asarray(c[k])) for k in a)

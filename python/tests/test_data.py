"""SynthChat language substrate: determinism, vocab structure, task shapes,
packing — the contract the Rust tokenizer/workload modules rely on."""

import numpy as np
import pytest

from compile import data
from compile.config import VOCAB_SIZE
from compile.data import ASST, BOS, EOS, PAD, USER, SynthChat, build_vocab


def test_vocab_deterministic():
    a, b = build_vocab(), build_vocab()
    assert a.words == b.words
    assert a.content_hash() == b.content_hash()


def test_vocab_fits_model_vocab_size():
    v = build_vocab()
    assert v.size <= VOCAB_SIZE
    assert len(set(v.words)) == v.size, "duplicate words"


def test_vocab_ranges_partition():
    v = build_vocab()
    ranges = [v.function_range, v.template_range, *v.topic_ranges, v.de_range]
    spans = sorted(ranges)
    assert spans[0][0] == len(data.SPECIAL_TOKENS)
    for (lo1, hi1), (lo2, _) in zip(spans, spans[1:]):
        assert hi1 == lo2, "ranges must tile contiguously"
    assert spans[-1][1] == v.size


def test_encode_decode_roundtrip():
    v = build_vocab()
    text = " ".join(v.words[5:25])
    assert v.decode(v.encode(text)) == text


def test_de_to_en_maps_into_topic_words():
    v = build_vocab()
    topic_ids = {i for lo, hi in v.topic_ranges for i in range(lo, hi)}
    assert all(en in topic_ids for en in v.de_to_en)
    assert len(v.de_to_en) == v.de_range[1] - v.de_range[0]


def test_examples_have_chat_template():
    synth = SynthChat()
    rng = np.random.default_rng(0)
    for task in data.TASKS:
        ex = synth.sample_example(rng, task)
        assert ex.task == task
        assert ex.prompt[0] == BOS and ex.prompt[1] == USER and ex.prompt[-1] == ASST
        assert len(ex.response) > 0
        assert all(0 <= t < synth.vocab.size for t in ex.prompt + ex.response)


def test_wmt_response_is_word_mapped_source():
    synth = SynthChat()
    rng = np.random.default_rng(1)
    ex = synth.sample_example(rng, "wmt")
    de = ex.prompt[3:-1]  # strip BOS, USER, marker ... ASST
    lo = synth.vocab.de_range[0]
    want = [synth.vocab.de_to_en[t - lo] for t in de]
    assert ex.response == want


def test_corpus_stream_tokens_in_range():
    synth = SynthChat()
    stream = synth.corpus_stream(seed=0)
    for _ in range(50):
        doc = next(stream)
        assert doc[-1] == EOS
        assert all(0 <= t < synth.vocab.size for t in doc)


def test_corpus_stream_deterministic():
    synth = SynthChat()
    a = [next(synth.corpus_stream(seed=5)) for _ in range(5)]
    b = [next(synth.corpus_stream(seed=5)) for _ in range(5)]
    # Streams are independent generators — re-create for a fair comparison.
    sa, sb = synth.corpus_stream(seed=5), synth.corpus_stream(seed=5)
    for _ in range(5):
        assert next(sa) == next(sb)
    del a, b


def test_pack_stream_chunks():
    synth = SynthChat()
    packed = data.pack_stream(synth.corpus_stream(seed=2), seq_len=32)
    for _ in range(10):
        chunk = next(packed)
        assert chunk.shape == (33,)
        assert chunk.dtype == np.int32
        assert PAD not in chunk  # packing never pads


def test_batch_stream_shape():
    synth = SynthChat()
    bs = data.batch_stream(synth.corpus_stream(seed=3), seq_len=16, batch=4)
    b = next(bs)
    assert b.shape == (4, 17)


def test_seed_prompts_cover_requested_tasks():
    synth = SynthChat()
    seeds = synth.seed_prompts(0, 12, ("dolly", "xsum", "cnndm"))
    tasks = {ex.task for ex in seeds}
    assert tasks == {"dolly", "xsum", "cnndm"}
    assert len(seeds) == 12
    # wmt excluded => OOD for distillation (Figure 3 setup).
    assert all(ex.task != "wmt" for ex in seeds)


def test_topic_keywords_deterministic():
    synth = SynthChat()
    for t in range(data.N_TOPICS):
        assert synth.grammar.topic_keywords(t) == synth.grammar.topic_keywords(t)

"""Training objectives: masked losses vs kernel forwards, and the TVD++
gradient's policy-gradient identity (paper Lemma 1 / Eq. 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses
from compile.kernels import dist_loss, ref

jax.config.update("jax_platform_name", "cpu")


def logits(rng, *shape, scale=2.0):
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


def test_masked_losses_reduce_to_unmasked():
    rng = np.random.default_rng(0)
    p, q = logits(rng, 12, 48), logits(rng, 12, 48)
    ones = jnp.ones(12)
    np.testing.assert_allclose(losses.masked_kld(p, q, ones), ref.kld(p, q), rtol=1e-5)
    np.testing.assert_allclose(losses.masked_tvd(p, q, ones), ref.tvd(p, q), rtol=1e-5)
    np.testing.assert_allclose(
        losses.masked_tvdpp(p, q, ones), ref.tvdpp_surrogate(p, q), rtol=1e-4, atol=1e-5
    )


def test_mask_excludes_positions():
    rng = np.random.default_rng(1)
    p, q = logits(rng, 8, 32), logits(rng, 8, 32)
    w = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    got = losses.masked_kld(p, q, w)
    want = ref.kld(p[:4], q[:4])
    np.testing.assert_allclose(got, want, rtol=1e-5)
    # Garbage in masked rows must not leak.
    p2 = p.at[5].set(1e5)
    np.testing.assert_allclose(losses.masked_kld(p2, q, w), want, rtol=1e-5)


def test_kernel_forward_equals_masked_loss_values():
    rng = np.random.default_rng(2)
    p, q = logits(rng, 20, 384), logits(rng, 20, 384)
    ones = jnp.ones(20)
    np.testing.assert_allclose(
        dist_loss.kld(p, q), losses.masked_kld(p, q, ones), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        dist_loss.tvd(p, q), losses.masked_tvd(p, q, ones), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        dist_loss.tvdpp_surrogate(p, q), losses.masked_tvdpp(p, q, ones), rtol=1e-3, atol=1e-4
    )


def test_tvdpp_gradient_is_normalized_policy_gradient():
    """Autodiff through masked_tvdpp must equal the analytic Eq. 1 gradient
    computed directly: d/dz_k of sum_x p(x) A(x) (-log p(x)) with A treated
    as constant (stop_gradient) is
        g_k = -(p_k A_k - p_k * sum_x p_x A_x).
    """
    rng = np.random.default_rng(3)
    n, v = 5, 24
    p_l, q_l = logits(rng, n, v), logits(rng, n, v)
    w = jnp.ones(n)
    grad = jax.grad(lambda z: losses.masked_tvdpp(z, q_l, w))(p_l)

    p = jax.nn.softmax(p_l, axis=-1)
    q = jax.nn.softmax(q_l, axis=-1)
    r = (q > p).astype(p.dtype)
    ep_r = jnp.sum(p * r, axis=-1)
    mu = jnp.mean(ep_r)
    var = jnp.mean(jnp.sum(p * (r - mu) ** 2, axis=-1))
    sigma = jnp.sqrt(var)
    adv = (r - mu) / (sigma + 1e-6)
    inner = jnp.sum(p * adv, axis=-1, keepdims=True)
    analytic = -(p * adv - p * inner) / n
    np.testing.assert_allclose(np.asarray(grad), np.asarray(analytic), rtol=1e-3, atol=1e-6)


def test_kld_gradient_is_p_minus_q():
    """Forward KL(q||p) wrt student logits has the classic softmax gradient
    (p - q)/N — a strong end-to-end check of the loss wiring."""
    rng = np.random.default_rng(4)
    n, v = 6, 16
    p_l, q_l = logits(rng, n, v), logits(rng, n, v)
    w = jnp.ones(n)
    grad = jax.grad(lambda z: losses.masked_kld(z, q_l, w))(p_l)
    p = jax.nn.softmax(p_l, axis=-1)
    q = jax.nn.softmax(q_l, axis=-1)
    np.testing.assert_allclose(np.asarray(grad), np.asarray((p - q) / n), rtol=1e-4, atol=1e-6)


def test_tvdpp_gradient_direction_reduces_tvd():
    """A small step along -grad(TVD++) should not increase TVD(p, q):
    the surrogate's whole point (Lemma 1: its gradient IS the TVD gradient
    up to advantage normalization)."""
    rng = np.random.default_rng(5)
    p_l, q_l = logits(rng, 10, 32), logits(rng, 10, 32)
    w = jnp.ones(10)
    g = jax.grad(lambda z: losses.masked_tvdpp(z, q_l, w))(p_l)
    before = float(ref.tvd(p_l, q_l))
    after = float(ref.tvd(p_l - 0.15 * g, q_l))
    assert after <= before + 1e-4, (before, after)


def test_next_token_loss_masked():
    rng = np.random.default_rng(6)
    lg = logits(rng, 2, 4, 8)
    labels = jnp.asarray(rng.integers(0, 8, (2, 4)), jnp.int32)
    w = jnp.zeros((2, 4)).at[0].set(1.0)
    got = float(losses.next_token_loss(lg, labels, w))
    want = float(ref.softmax_xent(lg[0], labels[0]))
    assert got == pytest.approx(want, rel=1e-5)


def test_distill_loss_dispatch():
    rng = np.random.default_rng(7)
    p, q = logits(rng, 4, 16), logits(rng, 4, 16)
    w = jnp.ones(4)
    for name in losses.LOSS_NAMES:
        val = float(losses.distill_loss(name, p, q, w))
        assert np.isfinite(val)
    with pytest.raises(ValueError):
        losses.distill_loss("nope", p, q, w)


def test_distill_loss_stops_teacher_gradient():
    rng = np.random.default_rng(8)
    p, q = logits(rng, 4, 16), logits(rng, 4, 16)
    w = jnp.ones(4)
    gq = jax.grad(lambda z: losses.distill_loss("kld", p, z, w))(q)
    np.testing.assert_allclose(np.asarray(gq), 0.0, atol=1e-12)

"""`specd distill` shard reader: byte-level parity with the Rust writer.

The test writes a dataset directory with its own independent encoder
(mirroring the layout documented in rust/src/dataset.rs), then checks that
compile.data.load_distill_shards reads it back exactly — format drift on
either side fails here.
"""

import json
import struct

import numpy as np
import pytest

from compile import data


def _fnv(b: bytes) -> int:
    return data._fnv1a64(b)


def _encode_record(seq_index, task_id, temperature, prompt, response, topk_rows):
    out = struct.pack("<QBfII", seq_index, task_id, temperature, len(prompt), len(response))
    out += struct.pack(f"<{len(prompt)}I", *prompt)
    if response:
        out += struct.pack(f"<{len(response)}I", *response)
    for ids, logits in topk_rows:
        out += struct.pack(f"<{len(ids)}I", *ids)
        out += struct.pack(f"<{len(logits)}f", *logits)
    return out


def _write_dataset(tmp_path, records_by_shard, topk, mix):
    shards = []
    total_records = 0
    total_tokens = 0
    for i, records in enumerate(records_by_shard):
        body = data.DISTILL_SHARD_MAGIC + struct.pack("<HH", topk, 0)
        for rec in records:
            body += _encode_record(*rec)
            total_records += 1
            total_tokens += len(rec[4])
        name = f"shard-{i:05d}.spds"
        (tmp_path / name).write_bytes(body)
        shards.append(
            {
                "file": name,
                "records": len(records),
                "response_tokens": sum(len(r[4]) for r in records),
                "bytes": len(body),
                "fnv64": f"{_fnv(body):016x}",
            }
        )
    manifest = {
        "format": data.DISTILL_FORMAT_TAG,
        "topk": topk,
        # String, matching the Rust writer (u64 > 2^53 would round as JSON).
        "seed": "0",
        "mix": [{"task": t, "weight": w} for t, w in mix],
        "temperatures": [0.0, 0.7],
        "top_p": 0.95,
        "max_new": 8,
        "records_per_shard": 4,
        "gamma": 3,
        "draft_model": "draft_tvdpp_ckpt4",
        "target_model": "target",
        "records_total": total_records,
        "response_tokens_total": total_tokens,
        "shards": shards,
    }
    (tmp_path / "manifest.json").write_text(json.dumps(manifest))


MIX = [("dolly", 0.5), ("cnndm", 0.3), ("xsum", 0.2)]


def _sample_records():
    # (seq_index, task_id, temperature, prompt, response, topk_rows)
    return [
        (0, 0, 0.0, [1, 3, 9, 4], [7, 8, 2], [([5, 2], [1.5, 0.25]),
                                              ([9, 0], [3.0, -1.0]),
                                              ([2, 7], [0.5, 0.125])]),
        (1, 2, 0.7, [1, 3, 5, 5, 4], [6], [([6, 1], [2.0, 1.0])]),
        (2, 1, 0.7, [1, 3, 5, 6, 4], [], []),
    ]


def test_reader_roundtrips_independent_writer(tmp_path):
    recs = _sample_records()
    _write_dataset(tmp_path, [recs[:2], recs[2:]], topk=2, mix=MIX)
    got = data.load_distill_shards(str(tmp_path))
    assert len(got) == 3
    assert [g.seq_index for g in got] == [0, 1, 2]
    assert [g.task for g in got] == ["dolly", "xsum", "cnndm"]
    assert got[0].prompt == [1, 3, 9, 4]
    assert got[0].response == [7, 8, 2]
    assert got[0].temperature == pytest.approx(0.0)
    assert got[1].temperature == pytest.approx(0.7)
    np.testing.assert_array_equal(got[0].topk_ids, [[5, 2], [9, 0], [2, 7]])
    np.testing.assert_allclose(got[0].topk_logits, [[1.5, 0.25], [3.0, -1.0], [0.5, 0.125]])
    assert got[2].response == [] and got[2].topk_ids.shape == (0, 2)
    # Descending-logit contract holds per row.
    assert (np.diff(got[0].topk_logits, axis=1) <= 0).all()


def test_reader_feeds_trainer_structure(tmp_path):
    _write_dataset(tmp_path, [_sample_records()], topk=2, mix=MIX)
    ds = data.distill_set_from_shards(str(tmp_path))
    assert ds[0] == ([1, 3, 9, 4, 7, 8, 2], 4)
    assert ds[1] == ([1, 3, 5, 5, 4, 6], 5)


def test_reader_rejects_corruption(tmp_path):
    _write_dataset(tmp_path, [_sample_records()], topk=2, mix=MIX)
    shard = tmp_path / "shard-00000.spds"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="checksum"):
        data.load_distill_shards(str(tmp_path))
    # Checksum verification can be bypassed explicitly (debugging), but the
    # size check still runs.
    raw.append(0)
    shard.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="size"):
        data.load_distill_shards(str(tmp_path), verify_checksums=False)


def test_reader_rejects_topk_zero_layout_mismatch(tmp_path):
    # topk=0 datasets carry no capture block; the reader must honor that.
    recs = [(0, 0, 0.0, [1, 4], [9, 9], [])]
    _write_dataset(tmp_path, [recs], topk=0, mix=MIX)
    got = data.load_distill_shards(str(tmp_path))
    assert got[0].topk_ids is None and got[0].topk_logits is None

"""Telemetry snapshot-ring contract: the JSON `specd --stats-out` writes
(and `GET /debug/stats` serves) must be internally consistent. Validates
the dump schema, monotone timestamps/sequence numbers, windowed-delta
consistency (rates derive from the window's counters) and health-flag
sanity — first against a synthetic dump shaped exactly like the Rust
`Telemetry::stats_json` output, then (when available) against a real
replay-produced dump.

CI produces the real dump with:

    specd replay --telemetry-window 0.05 --stats-out stats.json ...

and points this suite at it via ``SPECD_STATS_JSON``; without the env var
the replay half skips and the synthetic half still pins the validator.
"""

import json
import os

import pytest

# ---------------------------------------------------------------------------
# Validators (shared by the synthetic and replay halves)
# ---------------------------------------------------------------------------

TOP_KEYS = {
    "enabled", "window_s", "ring_capacity", "seq",
    "drift_active", "retune_advised", "drift_events", "latest", "ring",
}
SNAPSHOT_KEYS = {
    "seq", "unix_ms", "uptime_s", "window_s", "tokens", "blocks", "drafted",
    "accepted", "dispatches", "iterations", "lane_steps", "tokens_per_sec",
    "dispatches_per_sec", "accept_rate", "mean_accept_depth", "occupancy",
    "queue_depth", "pool_live", "pool_max", "ttft_p50", "ttft_p90",
    "itl_p50", "itl_p90", "slices", "health",
}
HEALTH_KEYS = {"baseline", "score", "drift_active", "retune_advised", "drift_events"}
SLICE_KEYS = {"tag", "blocks", "drafted", "accepted", "tokens"}


def close(a, b, tol=1e-6):
    return abs(a - b) <= tol * (1.0 + abs(a) + abs(b))


def validate_snapshot(s):
    missing = SNAPSHOT_KEYS - set(s)
    assert not missing, f"snapshot missing keys: {missing}"
    assert s["window_s"] > 0, s
    assert 0.0 <= s["accept_rate"] <= 1.0, s
    assert s["accepted"] <= s["drafted"], s

    # Windowed rates must derive from the window's own counters.
    if s["drafted"] > 0:
        assert close(s["accept_rate"], s["accepted"] / s["drafted"]), s
    else:
        assert s["accept_rate"] == 0.0, s
    if s["blocks"] > 0:
        assert close(s["mean_accept_depth"], s["accepted"] / s["blocks"]), s
    if s["iterations"] > 0:
        assert close(s["occupancy"], s["lane_steps"] / s["iterations"]), s
    assert close(s["tokens_per_sec"], s["tokens"] / s["window_s"]), s
    assert close(s["dispatches_per_sec"], s["dispatches"] / s["window_s"]), s

    # Per-tag slices partition the block-level counters exactly.
    for sl in s["slices"]:
        assert SLICE_KEYS <= set(sl), sl
    for key in ("blocks", "drafted", "accepted", "tokens"):
        total = sum(sl[key] for sl in s["slices"])
        assert total == s[key], f"slice {key} sum {total} != window total {s[key]}"

    # Latency quantiles are ordered and non-negative.
    assert 0.0 <= s["ttft_p50"] <= s["ttft_p90"], s
    assert 0.0 <= s["itl_p50"] <= s["itl_p90"], s

    h = s["health"]
    assert HEALTH_KEYS <= set(h), h
    assert h["score"] >= 0.0 and 0.0 <= h["baseline"] <= 1.0, h
    assert isinstance(h["drift_active"], bool) and isinstance(h["retune_advised"], bool), h
    assert h["drift_events"] >= 0, h
    # Current semantics: the machine-readable retune flag IS the latched
    # drift state (hysteresis applied upstream).
    assert h["retune_advised"] == h["drift_active"], h
    if h["drift_active"]:
        assert h["drift_events"] >= 1, "active drift implies at least one fire edge"


def validate(text):
    v = json.loads(text)
    assert isinstance(v, dict), "dump must be a JSON object"
    missing = TOP_KEYS - set(v)
    assert not missing, f"dump missing keys: {missing}"
    ring = v["ring"]
    assert isinstance(ring, list)
    assert len(ring) <= v["ring_capacity"], "ring overflows its capacity"
    for s in ring:
        validate_snapshot(s)
    if ring:
        assert v["latest"] == ring[-1], "latest must be the ring's newest snapshot"
        assert v["seq"] == ring[-1]["seq"]
        seqs = [s["seq"] for s in ring]
        assert seqs == list(range(seqs[0], seqs[0] + len(seqs))), \
            f"ring seqs must be contiguous and increasing: {seqs}"
        for a, b in zip(ring, ring[1:]):
            assert a["unix_ms"] <= b["unix_ms"], "unix timestamps must be monotone"
            assert a["uptime_s"] <= b["uptime_s"], "uptime must be monotone"
    else:
        assert v["latest"] is None
    assert v["retune_advised"] == v["drift_active"]
    return v


# ---------------------------------------------------------------------------
# Synthetic dump, shaped exactly like Telemetry::stats_json's output
# ---------------------------------------------------------------------------


def snap(seq, uptime, **kw):
    blocks, drafted, accepted, tokens = 4, 12, 8, 12
    s = {
        "seq": seq,
        "unix_ms": 1_700_000_000_000 + int(uptime * 1000),
        "uptime_s": uptime,
        "window_s": 1.0,
        "tokens": tokens,
        "blocks": blocks,
        "drafted": drafted,
        "accepted": accepted,
        "dispatches": 20,
        "iterations": 10,
        "lane_steps": 8,
        "tokens_per_sec": tokens / 1.0,
        "dispatches_per_sec": 20.0,
        "accept_rate": accepted / drafted,
        "mean_accept_depth": accepted / blocks,
        "occupancy": 0.8,
        "queue_depth": 1,
        "pool_live": 2,
        "pool_max": 4,
        "ttft_p50": 0.05,
        "ttft_p90": 0.09,
        "itl_p50": 0.004,
        "itl_p90": 0.008,
        "slices": [
            {"tag": "dolly", "blocks": 3, "drafted": 9, "accepted": 6, "tokens": 9},
            {"tag": "untagged", "blocks": 1, "drafted": 3, "accepted": 2, "tokens": 3},
        ],
        "health": {
            "baseline": 0.66, "score": 0.0, "drift_active": False,
            "retune_advised": False, "drift_events": 0,
        },
    }
    s.update(kw)
    return s


def synthetic_dump(n=5, **top):
    ring = [snap(i + 1, float(i + 1)) for i in range(n)]
    v = {
        "enabled": True,
        "window_s": 1.0,
        "ring_capacity": 240,
        "seq": ring[-1]["seq"] if ring else 0,
        "drift_active": False,
        "retune_advised": False,
        "drift_events": 0,
        "latest": ring[-1] if ring else None,
        "ring": ring,
    }
    v.update(top)
    return v


def test_synthetic_dump_validates():
    v = validate(json.dumps(synthetic_dump()))
    assert len(v["ring"]) == 5


def test_empty_ring_dump_validates():
    validate(json.dumps(synthetic_dump(n=0)))


def test_drifting_dump_validates():
    d = synthetic_dump()
    for s in d["ring"]:
        s["health"] = {
            "baseline": 0.7, "score": 0.31, "drift_active": True,
            "retune_advised": True, "drift_events": 1,
        }
    d["latest"] = d["ring"][-1]
    d["drift_active"] = d["retune_advised"] = True
    d["drift_events"] = 1
    validate(json.dumps(d))


def test_rejects_noncontiguous_seq():
    d = synthetic_dump()
    d["ring"][2]["seq"] = 99
    with pytest.raises(AssertionError, match="contiguous"):
        validate(json.dumps(d))


def test_rejects_inconsistent_accept_rate():
    d = synthetic_dump()
    d["ring"][0]["accept_rate"] = 0.99  # counters say 8/12
    with pytest.raises(AssertionError):
        validate(json.dumps(d))


def test_rejects_slice_sum_mismatch():
    d = synthetic_dump()
    d["ring"][0]["slices"][0]["tokens"] += 1
    with pytest.raises(AssertionError, match="slice"):
        validate(json.dumps(d))


def test_rejects_retune_flag_disagreeing_with_drift_state():
    d = synthetic_dump()
    d["ring"][-1]["health"]["retune_advised"] = True  # drift_active stays False
    d["latest"] = d["ring"][-1]
    with pytest.raises(AssertionError):
        validate(json.dumps(d))


# ---------------------------------------------------------------------------
# Replay-produced dump (CI wires SPECD_STATS_JSON to the smoke run's file)
# ---------------------------------------------------------------------------


def test_replay_dump_validates():
    path = os.environ.get("SPECD_STATS_JSON", "")
    if not path:
        pytest.skip("SPECD_STATS_JSON not set (no replay stats dump to validate)")
    if not os.path.exists(path):
        pytest.skip(f"replay stats dump {path} not found")
    with open(path) as f:
        v = validate(f.read())
    assert v["enabled"] is True, "replay smoke must run with telemetry enabled"
    assert v["ring"], "replay smoke must seal at least one window"
    # A real replay verifies blocks, so some window carries acceptance data.
    assert any(s["drafted"] > 0 for s in v["ring"]), \
        "no window observed any speculation blocks"

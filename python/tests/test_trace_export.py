"""Chrome trace-event export contract: the JSON `specd --trace-out` writes
must load in Perfetto. Validates span nesting, monotonic timestamps, track
metadata and request-lifecycle instants — first against a synthetic trace
shaped exactly like the Rust exporter's output, then (when available)
against a real replay-produced trace.

CI produces the real trace with:

    specd replay --trace-out trace.json ...

and points this suite at it via ``SPECD_TRACE_JSON``; without the env var
(or with artifacts missing) the replay half skips and the synthetic half
still pins the validator itself.
"""

import json
import os

import pytest

# ---------------------------------------------------------------------------
# Validators (shared by the synthetic and replay halves)
# ---------------------------------------------------------------------------

SCHED_CATS = {"sched", "phase", "dispatch"}
REQ_NAMES = {"req_queued", "req_admitted", "req_block", "req_terminal"}
# Scheduler-track instants not bound to a single request: name -> the arg
# keys the exporter must carry for that event.
SCHED_INSTANTS = {
    "drift": {"score_milli", "accept_rate_milli"},
    "fault": {"site"},
    "draft_swap": {"generation", "outcome"},
    "draft_rollback": {"generation", "trigger"},
    "sched_restart": {"count", "readmitted"},
}


def load_trace(text):
    """Parse and structurally validate a Chrome trace-event JSON string.

    Returns (metadata_events, duration_events, instant_events, ordered)
    where ``ordered`` is every non-metadata event in file order.
    """
    v = json.loads(text)
    assert isinstance(v, dict) and "traceEvents" in v, "top level must be {traceEvents: [...]}"
    events = v["traceEvents"]
    assert isinstance(events, list) and events, "traceEvents must be a non-empty array"

    metas, durs, instants, ordered = [], [], [], []
    for e in events:
        assert isinstance(e, dict) and "ph" in e and "pid" in e, e
        ph = e["ph"]
        if ph == "M":
            metas.append(e)
            continue
        assert "ts" in e and "tid" in e and "name" in e and "cat" in e, e
        ordered.append(e)
        if ph == "X":
            assert "dur" in e and e["dur"] >= 0, e
            assert e["cat"] in SCHED_CATS, f"unknown scheduler category: {e}"
            durs.append(e)
        elif ph == "i":
            assert e.get("s") == "t", f"instants must be thread-scoped: {e}"
            if e["name"] in SCHED_INSTANTS:
                # Scheduler-track instant (drift/fault/lifecycle), not
                # bound to any single request.
                assert e["cat"] in {"health", "fault"}, f"bad scheduler instant cat: {e}"
                assert SCHED_INSTANTS[e["name"]] <= set(e.get("args", {})), e
            else:
                assert e["name"] in REQ_NAMES, f"unknown request instant: {e}"
            instants.append(e)
        else:
            raise AssertionError(f"unexpected phase {ph!r}: {e}")
    return metas, durs, instants, ordered


def assert_tracks_named(metas):
    names = {
        (m["pid"], m["tid"]): m["args"]["name"]
        for m in metas
        if m.get("name") == "thread_name"
    }
    assert "scheduler" in names.values(), f"missing scheduler track: {names}"
    assert "requests" in names.values(), f"missing requests track: {names}"


def assert_monotonic(events):
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts), "exported events must be sorted by timestamp"
    assert all(t >= 0 for t in ts)


def assert_nesting(durs):
    """Every phase span must be contained in some iteration/wave span and
    every dispatch span in some enclosing phase-or-iteration span: ts/dur
    containment on one tid is exactly what Perfetto renders as nesting."""

    def contains(outer, inner):
        return (
            outer["tid"] == inner["tid"]
            and outer["ts"] <= inner["ts"]
            and inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        )

    tops = [e for e in durs if e["cat"] == "sched"]
    phases = [e for e in durs if e["cat"] == "phase"]
    dispatches = [e for e in durs if e["cat"] == "dispatch"]
    for p in phases:
        assert any(contains(t, p) for t in tops), f"orphan phase span: {p}"
    for d in dispatches:
        assert any(contains(e, d) for e in phases + tops), f"orphan dispatch span: {d}"


def assert_request_lifecycles(instants):
    """Per request: queued precedes admitted precedes the terminal, and
    there is exactly one terminal."""
    by_req = {}
    for e in instants:
        if e["name"] in SCHED_INSTANTS:
            continue  # scheduler-track instant, carries no request id
        by_req.setdefault(e["args"]["req"], []).append(e)
    assert by_req, "no request lifecycle instants in trace"
    for req, evs in by_req.items():
        names = [e["name"] for e in evs]
        assert names.count("req_terminal") == 1, f"request {req}: terminals {names}"
        assert names[-1] == "req_terminal", f"request {req}: events after terminal"
        if "req_queued" in names and "req_admitted" in names:
            assert names.index("req_queued") < names.index("req_admitted"), req


def validate(text):
    metas, durs, instants, ordered = load_trace(text)
    assert_tracks_named(metas)
    assert_monotonic(ordered)
    assert_nesting(durs)
    assert_request_lifecycles(instants)
    return durs, instants


# ---------------------------------------------------------------------------
# Synthetic trace, shaped exactly like rust/src/trace.rs's exporter
# ---------------------------------------------------------------------------


def _ev(name, cat, ts, dur, tid=1, **args):
    return {
        "pid": 1, "tid": tid, "ph": "X", "name": name, "cat": cat,
        "ts": ts, "dur": dur, "args": args,
    }


def _inst(name, ts, **args):
    return {
        "pid": 1, "tid": 2, "ph": "i", "s": "t", "name": name, "cat": "req",
        "ts": ts, "args": args,
    }


def synthetic_trace():
    events = [
        {"pid": 1, "tid": 1, "ph": "M", "name": "thread_name", "args": {"name": "scheduler"}},
        {"pid": 1, "tid": 2, "ph": "M", "name": "thread_name", "args": {"name": "requests"}},
        _inst("req_queued", 5, req=1),
        _inst("req_admitted", 40, req=1, queue_wait_us=35),
        _ev("wave", "sched", 50, 100, lanes=1, prompt_tokens=32),
        _ev("prefill", "dispatch", 60, 80, calls=1, bytes=4096),
        _ev("iteration", "sched", 200, 300, lane_steps=1, dispatches=5),
        _ev("draft_sync", "phase", 210, 40, lanes=1),
        _ev("decode", "dispatch", 215, 30, calls=1, bytes=128),
        _ev("verify", "phase", 260, 200, lanes=1),
        _ev("verify", "dispatch", 270, 180, calls=1, bytes=512),
        _inst("req_block", 505, req=1, accepted=2, emitted=3),
        {
            "pid": 1, "tid": 1, "ph": "i", "s": "t", "name": "drift",
            "cat": "health", "ts": 507,
            "args": {"score_milli": 180, "accept_rate_milli": 520},
        },
        _inst("req_terminal", 510, req=1, reason="ok", tokens_out=3),
        {
            "pid": 1, "tid": 1, "ph": "i", "s": "t", "name": "draft_swap",
            "cat": "health", "ts": 512,
            "args": {"generation": 2, "outcome": "adopted"},
        },
        {
            "pid": 1, "tid": 1, "ph": "i", "s": "t", "name": "sched_restart",
            "cat": "health", "ts": 514,
            "args": {"count": 1, "readmitted": 2},
        },
    ]
    events.sort(key=lambda e: e.get("ts", -1))
    return json.dumps({"traceEvents": events})


def test_synthetic_trace_validates():
    durs, instants = validate(synthetic_trace())
    assert len(durs) == 7
    assert len(instants) == 7


def test_validator_rejects_broken_nesting():
    v = json.loads(synthetic_trace())
    for e in v["traceEvents"]:
        if e.get("cat") == "phase" and e["name"] == "verify":
            e["dur"] = 10_000  # now overflows its iteration
    with pytest.raises(AssertionError, match="orphan phase"):
        validate(json.dumps(v))


def test_validator_rejects_double_terminal():
    v = json.loads(synthetic_trace())
    v["traceEvents"].append(
        _inst("req_terminal", 600, req=1, reason="ok", tokens_out=3)
    )
    with pytest.raises(AssertionError, match="terminals"):
        validate(json.dumps(v))


def test_validator_rejects_unsorted_timestamps():
    v = json.loads(synthetic_trace())
    v["traceEvents"].reverse()
    with pytest.raises(AssertionError, match="sorted"):
        validate(json.dumps(v))


# ---------------------------------------------------------------------------
# Replay-produced trace (CI wires SPECD_TRACE_JSON to the smoke run's file)
# ---------------------------------------------------------------------------


def test_replay_trace_validates():
    path = os.environ.get("SPECD_TRACE_JSON", "")
    if not path:
        pytest.skip("SPECD_TRACE_JSON not set (no replay trace to validate)")
    if not os.path.exists(path):
        pytest.skip(f"replay trace {path} not found")
    with open(path) as f:
        text = f.read()
    durs, instants = validate(text)
    # A real replay decodes at least one block for at least one request.
    assert any(e["name"] == "iteration" for e in durs), "no iteration spans in replay trace"
    assert any(e["name"] == "req_terminal" for e in instants)

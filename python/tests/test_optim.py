"""Optimizer substrate: AdamW dynamics + WarmupDecay schedule."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import optim

jax.config.update("jax_platform_name", "cpu")


def test_warmup_then_decay():
    lr = lambda s: float(optim.warmup_decay_lr(s, total_steps=100, lr_max=1.0,  # noqa: E731
                                               lr_min=0.1, warmup=10))
    assert lr(0) == 0.0
    assert lr(5) < lr(10)
    assert abs(lr(10) - 1.0) < 1e-6
    assert lr(50) < lr(10)
    assert abs(lr(100) - 0.1) < 1e-6
    assert abs(lr(500) - 0.1) < 1e-6  # clamped after total_steps


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.adamw_init(params)
    target = jnp.asarray([1.0, 2.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state = optim.adamw_update(params, grads, state, lr=0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)


def test_grad_clip_limits_update_norm():
    params = {"w": jnp.zeros(4)}
    state = optim.adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = optim.adamw_update(params, huge, state, lr=0.1, grad_clip=1.0, weight_decay=0.0)
    # With clipping, the first Adam step magnitude is ~lr per coordinate.
    assert float(jnp.max(jnp.abs(p2["w"]))) < 0.2


def test_weight_decay_skips_1d_params():
    params = {"norm": jnp.ones(4), "w": jnp.ones((4, 4))}
    state = optim.adamw_init(params)
    zero_grads = {"norm": jnp.zeros(4), "w": jnp.zeros((4, 4))}
    p2, _ = optim.adamw_update(params, zero_grads, state, lr=0.1, weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(p2["norm"]), 1.0)  # no decay on norms
    assert float(p2["w"][0, 0]) < 1.0  # decay applied to matrices


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(optim.global_norm(t)) - 5.0) < 1e-6


def test_step_counter_advances():
    params = {"w": jnp.ones(2)}
    state = optim.adamw_init(params)
    g = {"w": jnp.ones(2)}
    _, s1 = optim.adamw_update(params, g, state, lr=0.1)
    _, s2 = optim.adamw_update(params, g, s1, lr=0.1)
    assert int(s1["step"]) == 1 and int(s2["step"]) == 2

"""specd-lint rule contract: one violating + one clean fixture per rule,
the escape/marker grammar, the Rust line-scanner edge cases, and — last —
the end-to-end gate: the real repo must lint clean, because CI fails the
build on any violation.

Runs without cargo or any Rust toolchain: the analyzer is stdlib-only
Python over `rust/src/**`.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
sys.path.insert(0, os.path.join(REPO, "python"))

from tools.specd_lint.config import Config
from tools.specd_lint.model import parse_rust
from tools.specd_lint.rules import (
    Repo,
    rule_fault_site,
    rule_hot_path_alloc,
    rule_lock_order,
    rule_metrics_doc,
    rule_no_panic,
    rule_one_terminal,
    rule_trace_pairing,
    run_rules,
)


def repo_of(sources, docs=None, cfg=None):
    """Build a Repo from {filename: rust_source} fixtures."""
    files = [parse_rust(name, text) for name, text in sources.items()]
    return Repo(files=files, docs=docs or {}, cfg=cfg or Config())


# ---------------------------------------------------------------------------
# Scanner / model
# ---------------------------------------------------------------------------


class TestScanner:
    def test_strings_and_comments_are_blanked(self):
        rf = parse_rust(
            "spec.rs",
            'fn f() {\n'
            '    let s = "x.unwrap()"; // .unwrap() in comment\n'
            '    /* .unwrap() */\n'
            '}\n',
        )
        assert not any(".unwrap()" in line for line in rf.code)

    def test_raw_strings_and_char_literals(self):
        rf = parse_rust(
            "spec.rs",
            'fn f() {\n'
            '    let r = r#"panic!("in raw string")"#;\n'
            "    let c = '\\n';\n"
            "    let lt: &'static str = \"lifetime is not a char\";\n"
            '}\n',
        )
        assert not any("panic!" in line for line in rf.code)
        # The lifetime tick must not swallow the rest of the line as a
        # char literal.
        assert any("&'static str" in line for line in rf.code)

    def test_cfg_test_region_is_masked(self):
        rf = parse_rust(
            "spec.rs",
            "fn hot() {}\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    #[test]\n"
            "    fn t() { x.unwrap(); }\n"
            "}\n",
        )
        flagged = [i for i, t in enumerate(rf.is_test) if t]
        assert flagged, "test region must be detected"
        assert not rf.is_test[0], "non-test code stays unmasked"

    def test_function_spans_and_enclosing(self):
        rf = parse_rust(
            "x.rs",
            "fn alpha() {\n    body();\n}\n\nfn beta() {\n    body();\n}\n",
        )
        names = [n for n, _, _ in rf.functions]
        assert names == ["alpha", "beta"]
        assert rf.enclosing_function(2) == "alpha"
        assert rf.enclosing_function(6) == "beta"


# ---------------------------------------------------------------------------
# no-panic
# ---------------------------------------------------------------------------


class TestNoPanic:
    def test_unwrap_in_hot_module_flagged(self):
        repo = repo_of({"spec.rs": "fn f() { x.unwrap(); }\n"})
        v = rule_no_panic(repo)
        assert len(v) == 1 and v[0].rule == "no-panic" and v[0].line == 1

    def test_cold_module_and_test_code_are_exempt(self):
        repo = repo_of(
            {
                "eval.rs": "fn f() { x.unwrap(); }\n",  # not a hot module
                "spec.rs": "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n",
            }
        )
        assert rule_no_panic(repo) == []

    def test_allow_escape_with_reason_suppresses(self):
        repo = repo_of(
            {
                "spec.rs": "fn f() {\n"
                "    // lint: allow(no-panic, guarded by alloc above)\n"
                "    x.unwrap();\n"
                "}\n"
            }
        )
        assert rule_no_panic(repo) == []

    def test_allow_escape_without_reason_is_itself_flagged(self):
        repo = repo_of(
            {"spec.rs": "fn f() {\n    // lint: allow(no-panic, )\n    x.unwrap();\n}\n"}
        )
        v = rule_no_panic(repo)
        assert len(v) == 1
        assert "reason" in v[0].message

    def test_every_panic_macro_is_caught(self):
        for mac in ["panic!(\"x\")", "unreachable!()", "todo!()", "unimplemented!()"]:
            repo = repo_of({"spec.rs": f"fn f() {{ {mac}; }}\n"})
            assert rule_no_panic(repo), f"{mac} must be flagged"


# ---------------------------------------------------------------------------
# hot-path-alloc
# ---------------------------------------------------------------------------


class TestHotPathAlloc:
    def test_alloc_inside_region_flagged(self):
        repo = repo_of(
            {
                "spec.rs": "fn f() {\n"
                "    // lint: hot-path\n"
                "    let v = Vec::new();\n"
                "    // lint: end-hot-path\n"
                "    let w = Vec::new();\n"  # outside: fine
                "}\n"
            }
        )
        v = rule_hot_path_alloc(repo)
        assert len(v) == 1 and v[0].line == 3

    def test_unterminated_region_is_a_violation(self):
        repo = repo_of({"spec.rs": "fn f() {\n    // lint: hot-path\n}\n"})
        v = rule_hot_path_alloc(repo)
        assert len(v) == 1 and "never closed" in v[0].message

    def test_allow_escape_inside_region(self):
        repo = repo_of(
            {
                "spec.rs": "fn f() {\n"
                "    // lint: hot-path\n"
                "    // lint: allow(hot-path-alloc, cold error path)\n"
                "    let v = Vec::new();\n"
                "    // lint: end-hot-path\n"
                "}\n"
            }
        )
        assert rule_hot_path_alloc(repo) == []


# ---------------------------------------------------------------------------
# one-terminal
# ---------------------------------------------------------------------------

COORD_OK = """\
impl Coordinator {
    fn terminal(&self) {
        tx.send(Delta::Done);
    }
    fn other(&self) {
        self.terminal();
    }
}
"""

COORD_BAD = """\
impl Coordinator {
    fn terminal(&self) {
        tx.send(Delta::Done);
    }
    fn sneaky_exit(&self) {
        tx.send(Delta::Done);
    }
}
"""


class TestOneTerminal:
    def test_chokepoint_token_outside_terminal_flagged(self):
        v = rule_one_terminal(repo_of({"coordinator.rs": COORD_BAD}))
        assert v and all(x.rule == "one-terminal" for x in v)
        assert any("sneaky_exit" in x.message for x in v)

    def test_tokens_inside_terminal_are_fine(self):
        assert rule_one_terminal(repo_of({"coordinator.rs": COORD_OK})) == []

    def test_chokepoint_accepts_a_list_of_functions(self):
        # PR 10: the supervisor's stranded-request terminal is a second
        # legitimate chokepoint alongside Coordinator::terminal().
        src = (
            "fn terminal() { tx.send(Delta::Done); }\n"
            "pub fn strand_terminal() { tx.send(Delta::Done); }\n"
        )
        assert rule_one_terminal(repo_of({"coordinator.rs": src})) == []

    def test_empty_function_list_bans_tokens_outright(self):
        # lifecycle.rs must never send a terminal behind the
        # coordinator's back: its chokepoint list is empty.
        src = "fn helper() { tx.send(x); }\n"
        v = rule_one_terminal(repo_of({"lifecycle.rs": src}))
        assert v and all(x.rule == "one-terminal" for x in v)
        assert any("helper" in x.message for x in v)


# ---------------------------------------------------------------------------
# metrics-doc
# ---------------------------------------------------------------------------


def metrics_repo(defs, doc):
    return repo_of(
        {"metrics.rs": defs, "server.rs": "fn nothing() {}\n"},
        docs={"docs/METRICS.md": doc},
    )


class TestMetricsDoc:
    def test_defined_but_undocumented(self):
        repo = metrics_repo('fn r() { c(&mut s, "specd_orphan_total"); }\n', "| none |\n")
        v = rule_metrics_doc(repo)
        assert any("specd_orphan_total" in x.message and "missing" in x.message for x in v)

    def test_documented_but_not_defined(self):
        repo = metrics_repo(
            'fn r() { c(&mut s, "specd_real_total"); }\n',
            "| specd_real_total | | |\n| specd_ghost_total | | |\n",
        )
        v = rule_metrics_doc(repo)
        assert any("specd_ghost_total" in x.message for x in v)
        assert not any("specd_real_total" in x.message for x in v)

    def test_doc_glob_row_covers_prefixed_families(self):
        repo = metrics_repo(
            'fn r() { c(&mut s, "specd_sched_pool_live"); }\n',
            "| specd_sched_pool_* | | |\n",
        )
        assert rule_metrics_doc(repo) == []

    def test_stale_reference_in_other_module_flagged(self):
        repo = repo_of(
            {
                "metrics.rs": 'fn r() { c(&mut s, "specd_real_total"); }\n',
                "server.rs": "fn nothing() {}\n",
                "batch.rs": "// bumps specd_imaginary_total\nfn f() {}\n",
            },
            docs={"docs/METRICS.md": "| specd_real_total | | |\n"},
        )
        v = rule_metrics_doc(repo)
        assert any("specd_imaginary_total" in x.message for x in v)


# ---------------------------------------------------------------------------
# fault-site
# ---------------------------------------------------------------------------


class TestFaultSite:
    def test_unmarked_inject_flagged(self):
        repo = repo_of(
            {"runtime.rs": "fn f() {\n    crate::faults::inject(Site::RunLanes)?;\n}\n"}
        )
        v = rule_fault_site(repo)
        assert len(v) == 1 and "without a" in v[0].message

    def test_marked_inject_ok(self):
        repo = repo_of(
            {
                "runtime.rs": "fn f() {\n"
                "    // lint: fault-site(dispatch-run-lanes)\n"
                "    crate::faults::inject(Site::RunLanes)?;\n"
                "}\n"
            }
        )
        assert rule_fault_site(repo) == []

    def test_duplicate_id_flagged(self):
        repo = repo_of(
            {
                "runtime.rs": "fn f() {\n"
                "    // lint: fault-site(dup)\n"
                "    crate::faults::inject(Site::RunLanes)?;\n"
                "}\n",
                "exec.rs": "fn g() {\n"
                "    // lint: fault-site(dup)\n"
                "    crate::faults::inject(Site::ExecSend)?;\n"
                "}\n",
            }
        )
        v = rule_fault_site(repo)
        assert len(v) == 1 and "unique repo-wide" in v[0].message

    def test_stale_marker_flagged(self):
        repo = repo_of(
            {"runtime.rs": "fn f() {\n    // lint: fault-site(gone)\n    other();\n}\n"}
        )
        v = rule_fault_site(repo)
        assert len(v) == 1 and "stale" in v[0].message

    def test_faults_module_itself_exempt(self):
        repo = repo_of(
            {"faults.rs": "pub fn inject(s: Site) { faults::inject(s); }\n"}
        )
        assert rule_fault_site(repo) == []


# ---------------------------------------------------------------------------
# trace-pairing
# ---------------------------------------------------------------------------


class TestTracePairing:
    def test_unclosed_span_flagged(self):
        repo = repo_of({"batch.rs": "fn f() {\n    let t0 = trace::begin();\n}\n"})
        v = rule_trace_pairing(repo)
        assert len(v) == 1 and "t0" in v[0].message

    def test_closed_span_ok(self):
        repo = repo_of(
            {
                "batch.rs": "fn f() {\n"
                "    let t0 = trace::begin();\n"
                "    trace::phase(t0, Phase::Draft, 1);\n"
                "}\n"
            }
        )
        assert rule_trace_pairing(repo) == []

    def test_discarded_begin_flagged(self):
        repo = repo_of({"batch.rs": "fn f() {\n    trace::begin();\n}\n"})
        v = rule_trace_pairing(repo)
        assert len(v) == 1 and "discarded" in v[0].message


# ---------------------------------------------------------------------------
# lock-order
# ---------------------------------------------------------------------------


class TestLockOrder:
    def test_inverted_acquisition_flagged(self):
        repo = repo_of(
            {
                "server.rs": "fn f() {\n"
                "    let a = agg.lock();\n"
                "    let q = queue.lock();\n"
                "}\n"
            }
        )
        v = rule_lock_order(repo)
        assert len(v) == 1 and "queue -> agg" in v[0].message

    def test_configured_order_ok(self):
        repo = repo_of(
            {
                "server.rs": "fn f() {\n"
                "    let q = queue.lock();\n"
                "    let a = agg.lock();\n"
                "}\n"
            }
        )
        assert rule_lock_order(repo) == []

    def test_single_lock_functions_ignored(self):
        repo = repo_of({"server.rs": "fn f() { agg.lock(); }\nfn g() { queue.lock(); }\n"})
        assert rule_lock_order(repo) == []


# ---------------------------------------------------------------------------
# run_rules plumbing
# ---------------------------------------------------------------------------


def test_run_rules_filters_and_sorts():
    repo = repo_of(
        {"spec.rs": "fn f() {\n    x.unwrap();\n    let t0 = trace::begin();\n}\n"}
    )
    both = run_rules(repo)
    assert [v.rule for v in both] == ["no-panic", "trace-pairing"]
    only = run_rules(repo, only=["no-panic"])
    assert [v.rule for v in only] == ["no-panic"]


# ---------------------------------------------------------------------------
# End to end: the real repo lints clean, and the CLI exit codes hold
# ---------------------------------------------------------------------------


def lint_cli(*args):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "lint_specd.py"), *args],
        capture_output=True,
        text=True,
    )


def test_repo_is_clean_end_to_end():
    r = lint_cli()
    assert r.returncode == 0, f"repo must lint clean:\n{r.stdout}{r.stderr}"
    assert "specd-lint: OK" in r.stdout


def test_cli_fails_on_fixture_violation(tmp_path):
    bad = tmp_path / "rust" / "src"
    bad.mkdir(parents=True)
    (tmp_path / "Cargo.toml").write_text("[package]\nname = 'fixture'\n")
    (bad / "spec.rs").write_text("fn f() { x.unwrap(); }\n")
    r = lint_cli("--root", str(tmp_path))
    assert r.returncode == 1
    assert "no-panic" in r.stdout


def test_cli_list_rules():
    r = lint_cli("--list-rules")
    assert r.returncode == 0
    for rule in ["no-panic", "hot-path-alloc", "one-terminal", "metrics-doc",
                 "trace-pairing", "lock-order"]:
        assert rule in r.stdout

"""Training pipeline pieces: batched generation with KV caches, the
distillation dataset builder, finetune masking/mixing, and a tiny
end-to-end pipeline smoke run."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model, train
from compile.config import TARGET_CONFIG
from compile.data import ASST, BOS, EOS, SynthChat

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def target_params():
    # Untrained weights are fine: these tests exercise machinery, not quality.
    return model.init_params(TARGET_CONFIG, seed=0)


@pytest.fixture(scope="module")
def synth():
    return SynthChat()


def test_generate_batch_appends_and_respects_max_new(target_params, synth):
    rng = np.random.default_rng(0)
    prompts = [synth.sample_example(rng, "dolly").prompt for _ in range(3)]
    out = train.generate_batch(target_params, TARGET_CONFIG, prompts,
                               max_new=6, temperature=0.7, top_p=0.95, seed=1)
    assert len(out) == 3
    for seq, prompt in zip(out, prompts):
        assert seq[: len(prompt)] == prompt
        assert 1 <= len(seq) - len(prompt) <= 6
        assert all(0 <= t < TARGET_CONFIG.vocab_size for t in seq)


def test_generate_batch_greedy_deterministic(target_params, synth):
    rng = np.random.default_rng(1)
    prompts = [synth.sample_example(rng, "xsum").prompt for _ in range(2)]
    a = train.generate_batch(target_params, TARGET_CONFIG, prompts, 5, 0.0, 0.95, seed=1)
    b = train.generate_batch(target_params, TARGET_CONFIG, prompts, 5, 0.0, 0.95, seed=2)
    assert a == b, "greedy generation must be seed-independent"


def test_generate_batch_matches_sequential_greedy(target_params, synth):
    """Batched KV-cache generation == one-at-a-time full-recompute greedy."""
    rng = np.random.default_rng(2)
    prompt = synth.sample_example(rng, "cnndm").prompt
    got = train.generate_batch(target_params, TARGET_CONFIG, [prompt], 4, 0.0, 1.0, seed=0)[0]

    seq = list(prompt)
    for _ in range(4):
        logits = model.forward_train(target_params, TARGET_CONFIG,
                                     jnp.asarray([seq], jnp.int32))[0, -1]
        nxt = int(jnp.argmax(logits))
        seq.append(nxt)
        if nxt == EOS:
            break
    assert got == seq


def test_build_distill_dataset_structure(target_params, synth):
    tc = train.smoke_config()
    ds = train.build_distill_dataset(target_params, synth, tc,
                                     tasks=("dolly", "xsum"), seed=3)
    assert len(ds) == tc.distill_prompts * len(tc.distill_temperatures)
    for seq, plen in ds:
        assert seq[0] == BOS
        assert seq[plen - 1] == ASST, "prompt must end at the assistant marker"
        assert len(seq) > plen, "target must have generated something"


def test_finetune_checkpoint_hook_and_param_change(target_params, synth):
    tc = train.smoke_config()
    ds = train.build_distill_dataset(target_params, synth, tc, tasks=("dolly",), seed=4)
    draft0 = model.init_params(train.DRAFT_CONFIG, seed=5)
    saved = []
    out = train.finetune_draft(dict(draft0), target_params, ds, synth, tc,
                               "tvdpp", lambda ck, p: saved.append(ck))
    assert saved == list(range(1, tc.finetune_steps // max(1, tc.finetune_steps // tc.n_checkpoints) + 1))[: len(saved)]
    assert len(saved) == tc.n_checkpoints
    # Parameters must actually move.
    delta = sum(float(jnp.abs(out[k] - draft0[k]).sum()) for k in draft0)
    assert delta > 0.0


def test_captured_teacher_matches_live_teacher_with_full_capture(target_params, synth):
    """The sparse-teacher path fed a FULL (k = V) capture of the live
    teacher's logits must reproduce the live-teacher step exactly — pins
    the capture scatter + the captured_teacher jit branch."""
    tc = train.smoke_config()
    rng = np.random.default_rng(7)
    ex = synth.sample_example(rng, "dolly")
    seq = (ex.prompt + ex.response)[: tc.seq_len + 1]
    plen = len(ex.prompt)
    tokens = np.zeros((1, tc.seq_len + 1), np.int32)
    tokens[0, : len(seq)] = seq
    dist_w = np.zeros((1, tc.seq_len), np.float32)
    dist_w[0, plen - 1 : len(seq) - 1] = 1.0
    lm_w = np.zeros((1, tc.seq_len), np.float32)

    q_live = train.model.forward_train(target_params, TARGET_CONFIG,
                                       jnp.asarray(tokens[:, :-1]))
    draft0 = model.init_params(train.DRAFT_CONFIG, seed=11)
    opt0 = train.optim.adamw_init(draft0)
    args = (jnp.asarray(tokens), jnp.asarray(dist_w), jnp.asarray(lm_w))

    step_live = train.make_finetune_step("tvdpp", tc, 4)
    step_cap = train.make_finetune_step("tvdpp", tc, 4, captured_teacher=True)
    dummy = jnp.zeros((1,), jnp.float32)
    _, _, loss_live, ld_live, _ = step_live(dict(draft0), target_params, dict(opt0), *args, dummy)
    _, _, loss_cap, ld_cap, _ = step_cap(dict(draft0), target_params, dict(opt0), *args, q_live)
    np.testing.assert_allclose(float(ld_cap), float(ld_live), rtol=1e-5)
    np.testing.assert_allclose(float(loss_cap), float(loss_live), rtol=1e-5)


def test_finetune_draft_with_synthetic_capture_runs(target_params, synth):
    """finetune_draft over a shard-style capture (small k): params move and
    every loss variant accepts the sparse teacher."""
    tc = train.smoke_config()
    rng = np.random.default_rng(9)
    k, vocab = 4, TARGET_CONFIG.vocab_size
    distill_set, capture = [], []
    for _ in range(6):
        ex = synth.sample_example(rng, "xsum")
        seq = ex.prompt + ex.response
        n_resp = len(ex.response)
        ids = np.stack([rng.choice(vocab, size=k, replace=False) for _ in range(n_resp)])
        logits = np.sort(rng.normal(size=(n_resp, k)).astype(np.float32))[:, ::-1]
        distill_set.append((seq, len(ex.prompt)))
        capture.append((ids.astype(np.int64), np.ascontiguousarray(logits)))
    draft0 = model.init_params(train.DRAFT_CONFIG, seed=13)
    out = train.finetune_draft(dict(draft0), target_params, distill_set, synth, tc,
                               "tvd", lambda ck, p: None, capture=capture)
    delta = sum(float(jnp.abs(out[key] - draft0[key]).sum()) for key in draft0)
    assert delta > 0.0
    with pytest.raises(ValueError, match="parallel"):
        train.finetune_draft(dict(draft0), target_params, distill_set, synth, tc,
                             "tvd", lambda ck, p: None, capture=capture[:2])


@pytest.mark.slow
def test_pipeline_smoke_end_to_end(tmp_path):
    out = os.path.join(tmp_path, "run")
    train.run_pipeline(out, train.smoke_config(), include_wmt=False, seed=0)
    files = set(os.listdir(out))
    assert "target.npz" in files and "draft_base.npz" in files
    for loss in ("kld", "tvd", "tvdpp"):
        assert f"draft_{loss}_ckpt1.npz" in files
    assert "meta.json" in files
    # Checkpoints are loadable and have the draft architecture.
    p = train.load_params(os.path.join(out, "draft_tvdpp_ckpt1.npz"))
    assert set(p.keys()) == set(model.param_names(train.DRAFT_CONFIG))


def test_smoke_config_is_fast():
    tc = train.smoke_config()
    assert tc.pretrain_steps_target <= 16 and tc.finetune_steps <= 16


def test_distill_mix_ratio_rows():
    tc = train.TRAIN_CONFIG
    n_dist = int(round(tc.distill_mix_ratio * tc.batch_size))
    # Paper: 9:1 distillation:pretraining per batch.
    assert n_dist / tc.batch_size == pytest.approx(0.9, abs=0.1)
    assert 0 < n_dist < tc.batch_size
    assert data.TASKS == ("dolly", "xsum", "cnndm", "wmt")

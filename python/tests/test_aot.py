"""AOT export contract: HLO lowering, the state-vector layout, the SPCD1
weights format and the golden probes — everything the Rust loader trusts."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.config import ModelConfig

jax.config.update("jax_platform_name", "cpu")

TINY = ModelConfig(name="tiny", vocab_size=48, n_layers=1, n_heads=2, hidden=8,
                   intermediate=16, max_seq=32)


def test_state_layout_lengths():
    kvn = aot.kv_len(TINY)
    assert kvn == 1 * 2 * 32 * 2 * 4
    assert aot.state_len(TINY) == kvn + aot.PREFILL_BLOCK * TINY.vocab_size


def test_lower_entry_emits_hlo_text():
    text = aot.lower_entry(TINY, block=2, use_pallas=False)
    assert "ENTRY" in text and "HloModule" in text
    # One output: the state vector (non-tuple root) — the Rust contract.
    assert f"f32[{aot.state_len(TINY)}]" in text


def test_lowered_fn_matches_forward_cached():
    """Execute the state-layout function in JAX and compare against a direct
    forward_cached call: the layout plumbing must be value-preserving."""
    params = model.init_params(TINY, seed=0)
    names = model.param_names(TINY)
    kvn = aot.kv_len(TINY)
    block = 4
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, TINY.vocab_size, block).astype(np.int32))
    pos = jnp.asarray(0, jnp.int32)

    def fn(flat_params, state, tokens, pos):
        p = dict(zip(names, flat_params))
        kv = state[:kvn].reshape((TINY.n_layers, 2, TINY.max_seq, TINY.n_heads, TINY.head_dim))
        logits, kv2 = model.forward_cached(p, TINY, tokens, kv, pos, use_pallas=False)
        tail = state[kvn + block * TINY.vocab_size:]
        return jnp.concatenate([kv2.reshape(-1), logits.reshape(-1), tail])

    state0 = jnp.zeros(aot.state_len(TINY), jnp.float32)
    out = fn([params[n] for n in names], state0, toks, pos)
    logits_state = out[kvn:kvn + block * TINY.vocab_size].reshape(block, TINY.vocab_size)

    kv0 = model.init_kv(TINY)
    logits_direct, kv_direct = model.forward_cached(params, TINY, toks, kv0, pos, use_pallas=False)
    np.testing.assert_allclose(logits_state, logits_direct, rtol=1e-5)
    np.testing.assert_allclose(out[:kvn].reshape(kv_direct.shape), kv_direct, rtol=1e-5)


def test_weights_roundtrip(tmp_path):
    params = model.init_params(TINY, seed=1)
    path = os.path.join(tmp_path, "w.bin")
    aot.write_weights(path, {k: np.asarray(v) for k, v in params.items()})
    raw = open(path, "rb").read()
    assert raw[:6] == b"SPCD1\x00"
    (count,) = struct.unpack("<I", raw[6:10])
    assert count == len(params)
    # Names must appear in sorted order (the canonical arg order).
    off = 10
    prev = ""
    total = 0
    for _ in range(count):
        (nlen,) = struct.unpack("<H", raw[off:off + 2])
        off += 2
        name = raw[off:off + nlen].decode()
        off += nlen
        assert name > prev
        prev = name
        ndim = raw[off]
        off += 1
        dims = struct.unpack("<" + "I" * ndim, raw[off:off + 4 * ndim])
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        vals = np.frombuffer(raw[off:off + 4 * n], np.float32).reshape(dims)
        np.testing.assert_array_equal(vals, np.asarray(params[name]))
        off += 4 * n
        total += n
    assert off == len(raw)
    assert total == model.count_params(params)


def test_batched_fn_matches_per_lane_and_masks_pass_through():
    """The [B, T] batched function is the per-lane single function plus a
    mask select: active lanes equal the single path, masked lanes are
    bit-for-bit pass-throughs."""
    params = model.init_params(TINY, seed=3)
    names = model.param_names(TINY)
    flat = [params[n] for n in names]
    block, batch = 3, 4
    rng = np.random.default_rng(7)
    states = jnp.asarray(rng.normal(size=(batch, aot.state_len(TINY))).astype(np.float32))
    tokens = jnp.asarray(rng.integers(0, TINY.vocab_size, (batch, block)).astype(np.int32))
    pos = jnp.asarray([0, 0, 4, 9], jnp.int32)
    mask = jnp.asarray([1, 0, 1, 1], jnp.int32)

    out = np.asarray(aot.batched_fn(TINY, block, use_pallas=False)(
        flat, states, tokens, pos, mask))
    single = aot.state_fn(TINY, block, use_pallas=False)
    for b in range(batch):
        if int(mask[b]):
            want = np.asarray(single(flat, states[b], tokens[b], pos[b]))
            np.testing.assert_allclose(out[b], want, rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(out[b], np.asarray(states[b]))


def test_lower_entry_batched_emits_hlo_text():
    text = aot.lower_entry_batched(TINY, block=2, batch=3, use_pallas=False)
    assert "ENTRY" in text and "HloModule" in text
    # Non-tuple root: the [B, state_len] arena buffer threads call-to-call.
    assert f"f32[3,{aot.state_len(TINY)}]" in text


def test_lower_extract_batched_and_pack_emit_hlo_text():
    text = aot.lower_extract_batched(TINY, batch=3)
    assert f"f32[3,{aot.PREFILL_BLOCK * TINY.vocab_size}]" in text
    text = aot.lower_pack(TINY, batch=3)
    assert "dynamic-update-slice" in text


def test_pack_semantics_overwrite_one_lane():
    """The pack entry writes the whole incoming state over exactly one
    lane — recycled lanes need no zeroing."""
    sl = aot.state_len(TINY)
    rng = np.random.default_rng(11)
    states = jnp.asarray(rng.normal(size=(4, sl)).astype(np.float32))
    incoming = jnp.asarray(rng.normal(size=(sl,)).astype(np.float32))

    def pack(states, incoming, lane):
        return jax.lax.dynamic_update_slice(states, incoming[None, :], (lane, 0))

    out = np.asarray(pack(states, incoming, jnp.asarray(2, jnp.int32)))
    np.testing.assert_array_equal(out[2], np.asarray(incoming))
    for b in (0, 1, 3):
        np.testing.assert_array_equal(out[b], np.asarray(states[b]))


def test_golden_probe_batched_self_checks():
    params = {k: np.asarray(v) for k, v in model.init_params(TINY, seed=2).items()}
    probe = aot.golden_probe_batched(TINY, params, batch=3, block=4)
    assert probe["batch"] == 3 and probe["block"] == 4
    assert probe["mask"] == [1, 0, 1]
    assert len(probe["tokens"]) == 3 and len(probe["tokens"][0]) == 4
    assert len(probe["logits_head"]) == 3
    assert len(probe["logits_last_argmax"]) == 3
    # Deterministic (the Rust test replays it against the compiled exe).
    again = aot.golden_probe_batched(TINY, params, batch=3, block=4)
    assert probe == again


def test_golden_probe_prefill_wave_self_checks():
    """Ragged-wave prefill == sequential per-lane chunked prefill, pinned
    by the probe's own asserts (single-token, multi-chunk and
    exact-boundary prompts; idle lanes stay zero)."""
    params = {k: np.asarray(v) for k, v in model.init_params(TINY, seed=2).items()}
    probe = aot.golden_probe_prefill_wave(TINY, params, batch=5, block=4)
    assert probe["batch"] == 5 and probe["block"] == 4
    # 1 token, 2*block+3 = 11 (multi-chunk), block (exact boundary), 2.
    assert probe["lens"] == [1, 11, 4, 2]
    assert [len(p) for p in probe["prompts"]] == probe["lens"]
    assert len(probe["last_row_head"]) == 4 and len(probe["last_row_head"][0]) == 8
    assert len(probe["last_row_argmax"]) == 4
    # Deterministic (the Rust test replays it against the compiled exe).
    again = aot.golden_probe_prefill_wave(TINY, params, batch=5, block=4)
    assert probe == again


def test_golden_probe_prefill_wave_single_lane():
    """A width-1 wave degrades to plain chunked prefill."""
    params = {k: np.asarray(v) for k, v in model.init_params(TINY, seed=4).items()}
    probe = aot.golden_probe_prefill_wave(TINY, params, batch=1, block=4)
    assert probe["lens"] == [1]
    assert len(probe["last_row_head"]) == 1


def test_golden_probe_deterministic():
    params = {k: np.asarray(v) for k, v in model.init_params(TINY, seed=2).items()}
    a = aot.golden_probe(TINY, params, "verify", 4)
    b = aot.golden_probe(TINY, params, "verify", 4)
    assert a == b
    assert len(a["tokens"]) == 4
    assert len(a["logits_head"]) == 4 and len(a["logits_head"][0]) == 8


@pytest.mark.slow
def test_export_smoke(tmp_path):
    """Full export over a smoke-trained directory (exercises manifest and
    eval prompt generation)."""
    train_dir = os.path.join(tmp_path, "train")
    os.makedirs(train_dir)
    from compile.config import DRAFT_CONFIG, TARGET_CONFIG
    from compile.train import save_params
    save_params(os.path.join(train_dir, "target.npz"),
                model.init_params(TARGET_CONFIG, 0))
    save_params(os.path.join(train_dir, "draft_base.npz"),
                model.init_params(DRAFT_CONFIG, 1))
    out = os.path.join(tmp_path, "artifacts")
    aot.export(train_dir, out, batch_sizes=(2,))
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["format"] == "specd-artifacts-v1"
    assert set(manifest["models"]) == {"target", "draft_base"}
    assert manifest["models"]["draft_base"]["c_ratio"] < 0.05
    for arch in ("target", "draft"):
        assert manifest["arch"][arch]["batch_sizes"] == [2]
        for entry in ("prefill", "verify", "decode"):
            assert os.path.exists(os.path.join(out, "hlo", arch, f"{entry}.hlo.txt"))
            assert os.path.exists(os.path.join(out, "hlo", arch, f"{entry}.b2.hlo.txt"))
        for extra in ("extract.b2", "pack.b2"):
            assert os.path.exists(os.path.join(out, "hlo", arch, f"{extra}.hlo.txt"))
    golden = json.load(open(os.path.join(out, "golden.json")))
    for name in ("target", "draft_base"):
        assert set(golden[name]["batched"]) == {"2"}
        assert set(golden[name]["prefill_wave"]) == {"2"}
        wave = golden[name]["prefill_wave"]["2"]
        assert wave["block"] == aot.PREFILL_BLOCK
        assert wave["lens"] == [1, 2 * aot.PREFILL_BLOCK + 3], "clipped to batch=2"
        assert all(len(p) == L for p, L in zip(wave["prompts"], wave["lens"]))
    prompts = json.load(open(os.path.join(out, "eval_prompts.json")))
    assert set(prompts) == {"dolly", "xsum", "cnndm", "wmt"}

"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py),
swept over shapes and value regimes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import accept, attention, dist_loss, ref, rmsnorm, swiglu

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32


def arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape).astype(F32))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 300),
    h=st.sampled_from([8, 24, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(n, h, seed):
    rng = np.random.default_rng(seed)
    x, w = arr(rng, n, h), arr(rng, h)
    np.testing.assert_allclose(rmsnorm.rmsnorm(x, w), ref.rmsnorm(x, w), rtol=1e-5, atol=1e-5)


def test_rmsnorm_extreme_values():
    rng = np.random.default_rng(0)
    x = arr(rng, 4, 32, scale=1e4)
    w = arr(rng, 32)
    np.testing.assert_allclose(rmsnorm.rmsnorm(x, w), ref.rmsnorm(x, w), rtol=1e-4)


def test_rmsnorm_unit_gain_preserves_rms():
    rng = np.random.default_rng(1)
    x = arr(rng, 16, 64)
    y = np.asarray(rmsnorm.rmsnorm(x, jnp.ones(64)))
    rms = np.sqrt((y**2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# swiglu
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 130),
    h=st.sampled_from([16, 24, 128]),
    i=st.sampled_from([48, 64, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_swiglu_matches_ref(n, h, i, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, n, h)
    w1, w3 = arr(rng, h, i, scale=0.1), arr(rng, h, i, scale=0.1)
    w2 = arr(rng, i, h, scale=0.1)
    np.testing.assert_allclose(
        swiglu.swiglu(x, w1, w3, w2), ref.swiglu(x, w1, w3, w2), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([1, 2, 5, 8, 32]),
    s=st.sampled_from([64, 128, 256]),
    h=st.sampled_from([1, 3, 8]),
    d=st.sampled_from([8, 16]),
    pos_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(t, s, h, d, pos_frac, seed):
    rng = np.random.default_rng(seed)
    pos = int(pos_frac * (s - t))
    q = arr(rng, t, h, d)
    k, v = arr(rng, s, h, d), arr(rng, s, h, d)
    got = attention.attention(q, k, v, jnp.asarray(pos, jnp.int32))
    want = ref.attention(q, k, v, pos)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_ignores_stale_future_rows():
    """Rows beyond the query position must not affect the output — the
    invariant KV rollback relies on."""
    rng = np.random.default_rng(2)
    t, s, h, d = 2, 64, 3, 8
    q = arr(rng, t, h, d)
    k, v = arr(rng, s, h, d), arr(rng, s, h, d)
    pos = 10
    out1 = attention.attention(q, k, v, jnp.asarray(pos, jnp.int32))
    # Scribble garbage into rows pos+t.. (stale speculation).
    k2 = k.at[pos + t :].set(999.0)
    v2 = v.at[pos + t :].set(-999.0)
    out2 = attention.attention(q, k2, v2, jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


def test_attention_pos0_single_token_attends_self_only():
    rng = np.random.default_rng(3)
    q = arr(rng, 1, 2, 8)
    k, v = arr(rng, 32, 2, 8), arr(rng, 32, 2, 8)
    out = attention.attention(q, k, v, jnp.asarray(0, jnp.int32))
    # With only row 0 visible, output must equal v[0] exactly.
    np.testing.assert_allclose(out[0], v[0], rtol=1e-5)


# ---------------------------------------------------------------------------
# fused distillation losses
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 80),
    v=st.sampled_from([32, 384]),
    scale=st.sampled_from([0.5, 2.0, 8.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dist_losses_match_ref(n, v, scale, seed):
    rng = np.random.default_rng(seed)
    p, q = arr(rng, n, v, scale=scale), arr(rng, n, v, scale=scale)
    np.testing.assert_allclose(dist_loss.kld(p, q), ref.kld(p, q), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dist_loss.tvd(p, q), ref.tvd(p, q), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        dist_loss.tvdpp_surrogate(p, q), ref.tvdpp_surrogate(p, q), rtol=1e-3, atol=1e-4
    )


def test_losses_vanish_when_p_equals_q():
    rng = np.random.default_rng(4)
    p = arr(rng, 10, 64)
    assert float(dist_loss.kld(p, p)) == pytest.approx(0.0, abs=1e-5)
    assert float(dist_loss.tvd(p, p)) == pytest.approx(0.0, abs=1e-5)


def test_tvd_in_unit_interval():
    rng = np.random.default_rng(5)
    p, q = arr(rng, 20, 64, scale=5.0), arr(rng, 20, 64, scale=5.0)
    t = float(dist_loss.tvd(p, q))
    assert 0.0 <= t <= 1.0


def test_tvdpp_sigma_identity():
    """With p-weighted moments and a {0,1} reward, sigma^2 == mu(1-mu)
    exactly (Bernoulli) — pins the kernel's moment assembly."""
    rng = np.random.default_rng(6)
    p, q = arr(rng, 30, 128), arr(rng, 30, 128)
    _, mu, sigma = ref.tvdpp_stats(p, q)
    np.testing.assert_allclose(float(sigma) ** 2, float(mu) * (1 - float(mu)), rtol=1e-4)


# ---------------------------------------------------------------------------
# speculative acceptance
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    g=st.integers(1, 7),
    v=st.sampled_from([16, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_accept_matches_ref(g, v, seed):
    rng = np.random.default_rng(seed)
    p = jax.nn.softmax(arr(rng, g, v, scale=3.0))
    q = jax.nn.softmax(arr(rng, g, v, scale=3.0))
    toks = jnp.asarray(rng.integers(0, v, g), jnp.int32)
    us = jnp.asarray(rng.random(g), F32)
    na1, r1 = accept.sd_accept(p, q, toks, us)
    na2, r2 = ref.sd_accept(p, q, toks, us)
    assert int(na1) == int(na2)
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-6)


def test_accept_identical_distributions_accepts_all():
    rng = np.random.default_rng(7)
    g, v = 5, 32
    p = jax.nn.softmax(arr(rng, g, v))
    toks = jnp.asarray(rng.integers(0, v, g), jnp.int32)
    us = jnp.asarray(rng.random(g), F32)
    na, _ = accept.sd_accept(p, p, toks, us)
    assert int(na) == g


def test_accept_residual_is_distribution():
    rng = np.random.default_rng(8)
    g, v = 4, 64
    p = jax.nn.softmax(arr(rng, g, v, scale=4.0))
    q = jax.nn.softmax(arr(rng, g, v, scale=4.0))
    toks = jnp.asarray(rng.integers(0, v, g), jnp.int32)
    us = jnp.ones(g, F32) * 0.999  # force rejection quickly
    _, resid = accept.sd_accept(p, q, toks, us)
    resid = np.asarray(resid)
    assert resid.sum() == pytest.approx(1.0, abs=1e-5)
    assert (resid >= 0).all()

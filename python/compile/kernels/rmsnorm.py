"""Pallas RMSNorm kernel.

Tiling: grid over row blocks; each program normalizes a [BLOCK_ROWS, H] tile
held in VMEM with the [H] gain vector broadcast-resident. H is the model
hidden size (<= 128 here), so one tile is ~64KB at BLOCK_ROWS=128 — well
inside the ~16MB VMEM budget; on a real TPU we would raise BLOCK_ROWS until
the tile approaches the VPU-friendly 512 rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, ceil_div

BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """x: [N, H], w: [H] -> [N, H]; matches ref.rmsnorm."""
    n, h = x.shape
    block = min(BLOCK_ROWS, n)
    grid = (ceil_div(n, block),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), x.dtype),
        interpret=INTERPRET,
    )(x, w)

"""L1 Pallas kernels (interpret-mode) + pure-jnp oracles (ref)."""

from . import accept, attention, dist_loss, ref, rmsnorm, swiglu  # noqa: F401

"""Pallas fused SwiGLU MLP kernel: (silu(x@w1) * (x@w3)) @ w2.

Tiling: grid over row blocks of x; the three weight matrices stay resident
in VMEM across the grid (H*I*3*4B ~= 590KB at H=128, I=384 — VMEM-friendly;
at production sizes w1/w3/w2 would be streamed with a second grid axis over
the intermediate dim and an accumulator in scratch). The two first matmuls
feed the MXU back-to-back and the silu/multiply runs on the VPU without a
round-trip to HBM — that is the fusion the kernel exists for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, ceil_div

BLOCK_ROWS = 64


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    x = x_ref[...]
    a = x @ w1_ref[...]
    g = a * jax.nn.sigmoid(a)  # silu, on the VPU
    h = g * (x @ w3_ref[...])
    o_ref[...] = h @ w2_ref[...]


@jax.jit
def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """x: [N, H], w1/w3: [H, I], w2: [I, H] -> [N, H]; matches ref.swiglu."""
    n, h = x.shape
    i = w1.shape[1]
    block = min(BLOCK_ROWS, n)
    grid = (ceil_div(n, block),)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, h), lambda b: (b, 0)),
            pl.BlockSpec((h, i), lambda b: (0, 0)),
            pl.BlockSpec((h, i), lambda b: (0, 0)),
            pl.BlockSpec((i, h), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, h), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), x.dtype),
        interpret=INTERPRET,
    )(x, w1, w3, w2)

"""Pure-jnp oracles for every Pallas kernel (L1 correctness ground truth).

Each function here is the mathematical definition; the Pallas kernels in the
sibling modules must match these to float tolerance under hypothesis sweeps
(python/tests/test_kernels.py). These references are also the implementations
used on the *training* path (use_pallas=False) where interpret-mode Pallas
would be needlessly slow — the AOT export path uses the real kernels, and the
test suite pins kernel == ref so the two paths are interchangeable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: x * w / rms(x)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """Llama MLP: (silu(x@w1) * (x@w3)) @ w2."""
    a = x @ w1
    return (jax.nn.silu(a) * (x @ w3)) @ w2


def attention(q: jax.Array, k: jax.Array, v: jax.Array, q_pos0) -> jax.Array:
    """Position-masked multi-head attention.

    q: [T, H, D] queries for absolute positions q_pos0 .. q_pos0+T-1
    k, v: [S, H, D] cache buffers; row j holds the key/value for absolute
        position j (rows beyond the current sequence length contain stale
        garbage and are masked out by the position rule below).
    Visibility: query i attends to cache row j iff j <= q_pos0 + i.
    """
    T, H, D = q.shape
    S = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.array(D, q.dtype))
    logits = jnp.einsum("thd,shd->hts", q, k) * scale
    qpos = q_pos0 + jnp.arange(T)[:, None]
    mask = jnp.arange(S)[None, :] <= qpos  # [T, S]
    logits = jnp.where(mask[None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hts,shd->thd", probs, v)


# ---------------------------------------------------------------------------
# Distillation losses (paper §2.3). Convention: `p_logits` is the DRAFT
# (student, trainable), `q_logits` the TARGET (teacher, stop-gradient).
# All losses are means over the N token positions.
# ---------------------------------------------------------------------------


def kld(p_logits: jax.Array, q_logits: jax.Array) -> jax.Array:
    """Forward KL(q || p): the mass the teacher puts where the student doesn't."""
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    logq = jax.nn.log_softmax(q_logits, axis=-1)
    q = jnp.exp(logq)
    return jnp.mean(jnp.sum(q * (logq - logp), axis=-1))


def tvd(p_logits: jax.Array, q_logits: jax.Array) -> jax.Array:
    """Total variation distance 0.5 * sum |p - q| (Leviathan et al.: 1 - TVD
    equals the expected SD acceptance probability)."""
    p = jax.nn.softmax(p_logits, axis=-1)
    q = jax.nn.softmax(q_logits, axis=-1)
    return jnp.mean(0.5 * jnp.sum(jnp.abs(p - q), axis=-1))


def tvdpp_stats(p_logits: jax.Array, q_logits: jax.Array):
    """Reward moments for TVD++ (paper Eq. 1).

    Reward r(x) = 1{q(x) > p(x)} (Lemma 1). The paper computes mean/variance
    "over the input sequences and the entire vocabulary"; in the white-box
    (exact expectation) setting the natural weighting is the draft
    distribution p itself, since the policy-gradient expectation is under p:
        mu    = (1/N) sum_i sum_x p_i(x) r_i(x)
        sigma = sqrt((1/N) sum_i sum_x p_i(x) (r_i(x) - mu)^2)
    Returns (r, mu, sigma) with r of shape [N, V].
    """
    p = jax.nn.softmax(p_logits, axis=-1)
    q = jax.nn.softmax(q_logits, axis=-1)
    r = (q > p).astype(p.dtype)
    mu = jnp.mean(jnp.sum(p * r, axis=-1))
    var = jnp.mean(jnp.sum(p * jnp.square(r - mu), axis=-1))
    return r, mu, jnp.sqrt(var)


def tvdpp_surrogate(p_logits: jax.Array, q_logits: jax.Array, eps: float = 1e-6) -> jax.Array:
    """TVD++ surrogate loss whose gradient is paper Eq. 1 (exact-expectation
    form): grad = E_{x~p}[ grad log p(x) * (-(r(x)-mu)/sigma) ].

    Implemented as -(1/N) sum_i sum_x sg(p_i(x) * A_i(x)) * log p_i(x) with
    A = (r - mu)/(sigma + eps) and sg() = stop_gradient, so autodiff yields
    exactly the policy gradient with normalized advantage.
    """
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    r, mu, sigma = tvdpp_stats(p_logits, q_logits)
    adv = (r - mu) / (sigma + eps)
    weight = jax.lax.stop_gradient(jnp.exp(logp) * adv)
    return -jnp.mean(jnp.sum(weight * logp, axis=-1))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy; labels [N] int, logits [N, V]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


# ---------------------------------------------------------------------------
# Speculative decoding acceptance (Leviathan et al. modified rejection
# sampling) — reference for the Pallas accept kernel AND for the Rust
# `sampling::rejection` implementation (pinned via golden vectors).
# ---------------------------------------------------------------------------


def sd_accept(p: jax.Array, q: jax.Array, tokens: jax.Array, uniforms: jax.Array):
    """Vectorized acceptance of a draft block.

    p: [G, V] draft distributions, q: [G, V] target distributions,
    tokens: [G] drafted token ids, uniforms: [G] U(0,1) samples.
    Returns (n_accept, residual) where n_accept is the number of accepted
    draft tokens (0..G) and residual is norm(max(q-p, 0)) at the first
    rejected position (or q[G-1] placeholder if everything was accepted —
    callers then sample the bonus token from the *next* target distribution).
    """
    G, V = p.shape
    p_tok = jnp.take_along_axis(p, tokens[:, None], axis=-1)[:, 0]
    q_tok = jnp.take_along_axis(q, tokens[:, None], axis=-1)[:, 0]
    accept = uniforms < jnp.minimum(1.0, q_tok / jnp.maximum(p_tok, 1e-20))
    # First rejection index; G if none.
    rejected = jnp.logical_not(accept)
    n_accept = jnp.argmax(jnp.concatenate([rejected, jnp.array([True])]))
    idx = jnp.minimum(n_accept, G - 1)
    resid = jnp.maximum(q[idx] - p[idx], 0.0)
    z = jnp.sum(resid)
    resid = jnp.where(z > 0, resid / jnp.maximum(z, 1e-20), q[idx])
    return n_accept, resid

"""Pallas fused distillation-loss kernel (KLD / TVD / TVD++ in one pass).

The distillation hot-spot is a reduction over [N, V] draft and target logit
matrices (N = batch*seq token positions). A naive implementation makes four
separate passes (softmax p, softmax q, each loss); this kernel fuses them:
one pass over vocab tiles per token block, producing the five per-token
scalars from which every loss and the TVD++ moments are assembled:

    a_i   = sum_x p_i(x) * r_i(x)              (E_p[r], r = 1{q > p})
    c_i   = sum_x p_i(x) * r_i(x) * log p_i(x)
    d_i   = sum_x p_i(x) * log p_i(x)          (negative entropy)
    kld_i = sum_x q_i(x) * (log q_i(x) - log p_i(x))
    tvd_i = 0.5 * sum_x |p_i(x) - q_i(x)|

Host-side combination (see `tvdpp_from_parts`):
    mu      = mean(a),  sigma^2 = mu - mu^2   (Bernoulli under p-weighting —
              an identity the tests pin against ref.tvdpp_stats)
    tvd++_i = -(c_i - mu * d_i) / (sigma + eps)

The two softmaxes are computed inside the tile pass with the standard
max-shift; V fits one VMEM tile at our scale (512 * 4B rows), so the grid is
over token blocks only. At production vocab sizes a second grid axis over
vocab tiles with SMEM accumulators does the same reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, ceil_div

BLOCK_N = 64


def _dist_loss_kernel(p_ref, q_ref, a_ref, c_ref, d_ref, kld_ref, tvd_ref):
    pl_logits = p_ref[...]
    ql_logits = q_ref[...]
    logp = jax.nn.log_softmax(pl_logits, axis=-1)
    logq = jax.nn.log_softmax(ql_logits, axis=-1)
    p = jnp.exp(logp)
    q = jnp.exp(logq)
    r = (q > p).astype(p.dtype)
    a_ref[...] = jnp.sum(p * r, axis=-1)
    c_ref[...] = jnp.sum(p * r * logp, axis=-1)
    d_ref[...] = jnp.sum(p * logp, axis=-1)
    kld_ref[...] = jnp.sum(q * (logq - logp), axis=-1)
    tvd_ref[...] = 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)


@jax.jit
def dist_loss_parts(p_logits: jax.Array, q_logits: jax.Array):
    """Fused per-token loss parts. p/q_logits: [N, V] -> five [N] vectors."""
    n, v = p_logits.shape
    block = min(BLOCK_N, n)
    grid = (ceil_div(n, block),)
    vec = lambda: jax.ShapeDtypeStruct((n,), p_logits.dtype)  # noqa: E731
    spec2 = pl.BlockSpec((block, v), lambda i: (i, 0))
    spec1 = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _dist_loss_kernel,
        grid=grid,
        in_specs=[spec2, spec2],
        out_specs=[spec1, spec1, spec1, spec1, spec1],
        out_shape=[vec(), vec(), vec(), vec(), vec()],
        interpret=INTERPRET,
    )(p_logits, q_logits)


def tvdpp_from_parts(a, c, d, eps: float = 1e-6):
    """Assemble the TVD++ surrogate from the fused per-token parts."""
    mu = jnp.mean(a)
    sigma = jnp.sqrt(jnp.maximum(mu - mu * mu, 0.0))
    return -jnp.mean((c - mu * d) / (sigma + eps))


def kld(p_logits, q_logits):
    _, _, _, k, _ = dist_loss_parts(p_logits, q_logits)
    return jnp.mean(k)


def tvd(p_logits, q_logits):
    _, _, _, _, t = dist_loss_parts(p_logits, q_logits)
    return jnp.mean(t)


def tvdpp_surrogate(p_logits, q_logits, eps: float = 1e-6):
    """Forward value of the TVD++ surrogate (gradient path lives in the ref
    implementation used for training; tests pin kernel == ref forward)."""
    a, c, d, _, _ = dist_loss_parts(p_logits, q_logits)
    return tvdpp_from_parts(a, c, d, eps)

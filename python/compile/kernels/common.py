"""Shared helpers for the Pallas kernels.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path is both the correctness vehicle
(pytest/hypothesis vs ref.py) and what lowers into the AOT-exported HLO.
The BlockSpec tilings are nevertheless written as they would be for a real
TPU: VMEM-resident blocks, last dim padded toward lane width where shapes
allow; DESIGN.md §Hardware-Adaptation records the production tiling.
"""

from __future__ import annotations

INTERPRET = True  # flip only on a real TPU backend


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b

"""Pallas speculative-acceptance kernel (Leviathan et al. rejection rule).

Given a drafted block — draft distributions p[G, V], target distributions
q[G, V], drafted tokens and U(0,1) samples — compute per-position acceptance
indicators and the (unnormalized) residual distributions max(q - p, 0).

Used by the python-side offline SD simulator (train.py checkpoint selection)
and as the golden reference for the Rust `sampling::rejection` hot path:
python/tests/test_accept.py writes golden vectors that
rust/tests/ integration tests replay bit-for-bit.

Token-probability lookup is done MXU-style with a one-hot contraction
(gather is hostile to the TPU vector unit; a [G, V] one-hot matmul is free
at these shapes and stays in VMEM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET


def _accept_kernel(p_ref, q_ref, tok_ref, u_ref, acc_ref, resid_ref):
    p = p_ref[...]
    q = q_ref[...]
    g, v = p.shape
    onehot = (jax.lax.iota(jnp.int32, v)[None, :] == tok_ref[...][:, None]).astype(p.dtype)
    p_tok = jnp.sum(p * onehot, axis=-1)
    q_tok = jnp.sum(q * onehot, axis=-1)
    ratio = jnp.minimum(1.0, q_tok / jnp.maximum(p_tok, 1e-20))
    acc_ref[...] = (u_ref[...] < ratio).astype(p.dtype)
    resid_ref[...] = jnp.maximum(q - p, 0.0)


@jax.jit
def sd_accept_parts(p: jax.Array, q: jax.Array, tokens: jax.Array, uniforms: jax.Array):
    """p, q: [G, V]; tokens: [G] int32; uniforms: [G] -> (accept[G], resid[G, V])."""
    g, v = p.shape
    spec2 = pl.BlockSpec((g, v), lambda: (0, 0))
    spec1 = pl.BlockSpec((g,), lambda: (0,))
    return pl.pallas_call(
        _accept_kernel,
        grid=(),
        in_specs=[spec2, spec2, spec1, spec1],
        out_specs=[spec1, spec2],
        out_shape=[
            jax.ShapeDtypeStruct((g,), p.dtype),
            jax.ShapeDtypeStruct((g, v), p.dtype),
        ],
        interpret=INTERPRET,
    )(p, q, tokens.astype(jnp.int32), uniforms)


def sd_accept(p, q, tokens, uniforms):
    """Full acceptance decision; matches ref.sd_accept exactly."""
    accept, resid_all = sd_accept_parts(p, q, tokens, uniforms)
    g = p.shape[0]
    rejected = accept < 0.5
    n_accept = jnp.argmax(jnp.concatenate([rejected, jnp.array([True])]))
    idx = jnp.minimum(n_accept, g - 1)
    resid = resid_all[idx]
    z = jnp.sum(resid)
    resid = jnp.where(z > 0, resid / jnp.maximum(z, 1e-20), q[idx])
    return n_accept, resid

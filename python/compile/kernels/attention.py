"""Pallas flash-style position-masked attention kernel.

This is the serving hot-spot: both the draft decode step (T=1) and the
target verify step (T=gamma+1) run it against a fixed-capacity KV cache of
S rows where only rows with absolute position <= current position are live.

TPU adaptation of the GPU flash pattern (DESIGN.md §Hardware-Adaptation):
  - grid axis over heads; per program the [T, D] query tile sits in VMEM,
  - K/V are streamed in [BLOCK_S, D] tiles (the BlockSpec expresses the
    HBM->VMEM schedule a CUDA kernel would do with threadblocks + smem),
  - online softmax: running max m, running denominator l, accumulator acc —
    one pass over the cache, no [T, S] logits matrix ever materialized,
  - masking is by *absolute position* (row j visible to query i iff
    j <= q_pos0 + i), which is what makes KV rollback in the Rust
    coordinator a pure length-bookkeeping operation: stale rows beyond the
    current length are simply never visible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, ceil_div

BLOCK_S = 64
NEG_INF = -1e30


def _attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_s: int, s_total: int):
    """One head. q_ref: [T, D]; k_ref/v_ref: [S, D]; pos_ref: [1] int32."""
    t, d = q_ref.shape
    q = q_ref[...]
    pos0 = pos_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.array(d, q.dtype))
    qpos = pos0 + jax.lax.iota(jnp.int32, t)  # absolute query positions

    m = jnp.full((t, 1), NEG_INF, q.dtype)  # running max
    l = jnp.zeros((t, 1), q.dtype)  # running denominator
    acc = jnp.zeros((t, d), q.dtype)

    def body(sb, carry):
        m, l, acc = carry
        kblk = k_ref[pl.dslice(sb * block_s, block_s), :]
        vblk = v_ref[pl.dslice(sb * block_s, block_s), :]
        logits = (q @ kblk.T) * scale  # [T, BLOCK_S]
        kpos = sb * block_s + jax.lax.iota(jnp.int32, block_s)
        visible = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(visible, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        correction = jnp.exp(m - m_new)
        pexp = jnp.exp(logits - m_new)
        l_new = l * correction + jnp.sum(pexp, axis=-1, keepdims=True)
        acc_new = acc * correction + pexp @ vblk
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, ceil_div(s_total, block_s), body, (m, l, acc))
    o_ref[...] = acc / jnp.maximum(l, 1e-20)


@jax.jit
def attention(q: jax.Array, k: jax.Array, v: jax.Array, q_pos0: jax.Array) -> jax.Array:
    """q: [T, H, D]; k, v: [S, H, D]; q_pos0: int32 scalar. Matches ref.attention."""
    t, h, d = q.shape
    s = k.shape[0]
    block_s = min(BLOCK_S, s)
    pos = jnp.reshape(q_pos0.astype(jnp.int32), (1,))
    # Head-major layout so each grid program owns one head's tiles.
    qh = jnp.transpose(q, (1, 0, 2))  # [H, T, D]
    kh = jnp.transpose(k, (1, 0, 2))
    vh = jnp.transpose(v, (1, 0, 2))
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_s=block_s, s_total=s),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),  # None squeezes the head axis
            pl.BlockSpec((None, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, d), q.dtype),
        interpret=INTERPRET,
    )(pos, qh, kh, vh)
    return jnp.transpose(out, (1, 0, 2))  # back to [T, H, D]

"""L2 training objectives (masked variants of the kernel losses).

The kernels/ref.py losses operate on flat [N, V] logits; training needs
per-position masking (loss only on response tokens of distillation
sequences, paper §2.3) and the TVD++ moments taken over exactly the masked
token set ("over the input sequences and the entire vocabulary", Eq. 1).
The implementations here are the gradient path; tests pin them against the
unmasked kernel forwards on all-ones masks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LOSS_NAMES = ("kld", "tvd", "tvdpp")


def _wmean(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.sum(x * w) / jnp.maximum(jnp.sum(w), 1.0)


def masked_kld(p_logits, q_logits, w):
    """Forward KL(q || p), masked mean. p/q: [..., V], w: [...] weights."""
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    logq = jax.nn.log_softmax(q_logits, axis=-1)
    per = jnp.sum(jnp.exp(logq) * (logq - logp), axis=-1)
    return _wmean(per, w)


def masked_tvd(p_logits, q_logits, w):
    p = jax.nn.softmax(p_logits, axis=-1)
    q = jax.nn.softmax(q_logits, axis=-1)
    per = 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)
    return _wmean(per, w)


def masked_tvdpp(p_logits, q_logits, w, eps: float = 1e-6):
    """TVD++ (paper Eq. 1): policy gradient with advantage normalization.

    mu/sigma are the p-weighted reward moments over the masked positions and
    the whole vocabulary; the surrogate's gradient is
    E_{x~p}[grad log p(x) * (-(r(x)-mu)/sigma)] averaged over masked tokens.
    """
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    p = jnp.exp(logp)
    q = jax.nn.softmax(q_logits, axis=-1)
    r = (q > p).astype(p.dtype)
    ep_r = jnp.sum(p * r, axis=-1)  # [...]: E_p[r] per position
    mu = _wmean(ep_r, w)
    var = _wmean(jnp.sum(p * jnp.square(r - mu), axis=-1), w)
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    adv = (r - mu) / (sigma + eps)
    weight = jax.lax.stop_gradient(p * adv)
    per = -jnp.sum(weight * logp, axis=-1)
    return _wmean(per, w)


def distill_loss(name: str, p_logits, q_logits, w):
    q_logits = jax.lax.stop_gradient(q_logits)
    if name == "kld":
        return masked_kld(p_logits, q_logits, w)
    if name == "tvd":
        return masked_tvd(p_logits, q_logits, w)
    if name == "tvdpp":
        return masked_tvdpp(p_logits, q_logits, w)
    raise ValueError(f"unknown distillation loss {name!r}")


def next_token_loss(logits, labels, w):
    """Masked mean cross entropy. logits: [..., V], labels/w: [...]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return _wmean(-ll, w)

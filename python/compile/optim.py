"""AdamW + WarmupDecay LR schedule (paper §A.3), implemented from scratch
(no optax in this environment). Pytree-generic over flat param dicts.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def warmup_decay_lr(step, total_steps: int, lr_max: float, lr_min: float, warmup: int):
    """Linear warmup to lr_max, then linear decay to lr_min (WarmUpDecayLR)."""
    step = jnp.asarray(step, jnp.float32)
    warm = lr_max * step / jnp.maximum(warmup, 1)
    frac = (step - warmup) / jnp.maximum(total_steps - warmup, 1)
    decay = lr_max + (lr_min - lr_max) * jnp.clip(frac, 0.0, 1.0)
    return jnp.where(step < warmup, warm, decay)


def adamw_init(params) -> Dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def adamw_update(
    params,
    grads,
    state,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float = 1.0,
) -> Tuple[Dict, Dict]:
    """One AdamW step with global-norm clipping. Norm gains (1-D params) are
    excluded from weight decay, matching standard LLM practice."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        update = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        wd = weight_decay if p.ndim > 1 else 0.0
        return p - lr * (update + wd * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}

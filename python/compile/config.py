"""Model / training configuration for the specd reproduction.

Mirrors paper Table 1 (Llama 2-Chat 7B target vs 115M drafter) scaled to a
CPU-trainable size while preserving the architecture family (RMSNorm + RoPE +
SiLU MLP, Llama-2 style) and — approximately — the draft:target parameter
ratio c that enters the paper's MBSU metric. The *actual* ratio is computed
from realized parameter counts at export time and recorded in the artifact
manifest; the Rust side reads c from there rather than hard-coding 1.64%.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Llama-2-family decoder-only transformer configuration."""

    name: str
    vocab_size: int = 512
    n_layers: int = 8
    n_heads: int = 8
    hidden: int = 128
    intermediate: int = 384
    max_seq: int = 256
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.n_heads == 0
        return self.hidden // self.n_heads

    def param_count(self) -> int:
        """Exact parameter count of init_params for this config."""
        embed = self.vocab_size * self.hidden
        unembed = 0 if self.tie_embeddings else self.vocab_size * self.hidden
        per_layer = (
            4 * self.hidden * self.hidden  # wq wk wv wo
            + 3 * self.hidden * self.intermediate  # w1 w3 w2
            + 2 * self.hidden  # attn_norm, mlp_norm
        )
        final_norm = self.hidden
        return embed + unembed + self.n_layers * per_layer + final_norm


# Paper Table 1, scaled. Target plays the role of Llama 2-Chat 7B; draft the
# role of Llama 2-Chat-Drafter 115M (1.64% of target). Realized ratio here is
# ~1.7% (tied draft embeddings); the manifest records the exact value and the
# Rust MBSU metric consumes it from there.
VOCAB_SIZE = 384  # SynthChat vocabulary (see data.build_vocab; <= 384 words)

TARGET_CONFIG = ModelConfig(
    name="target",
    vocab_size=VOCAB_SIZE,
    n_layers=6,
    n_heads=8,
    hidden=128,
    intermediate=384,
    tie_embeddings=False,
)

DRAFT_CONFIG = ModelConfig(
    name="draft",
    vocab_size=VOCAB_SIZE,
    n_layers=2,
    n_heads=3,
    hidden=24,
    intermediate=64,
    tie_embeddings=True,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters for the three training phases (paper §A.3, scaled)."""

    seed: int = 0
    batch_size: int = 16
    seq_len: int = 128
    # Phase 1: pretraining (next-token prediction on SynthChat corpus).
    pretrain_steps_draft: int = 3000
    pretrain_steps_target: int = 3000
    # Chat finetuning of the *target* (to make it "chat-fine-tuned").
    target_sft_steps: int = 1500
    # Phase 2: distillation dataset generation.
    distill_prompts: int = 384
    distill_temperatures: tuple = (0.0, 0.3, 0.7, 1.0)
    distill_top_p: float = 0.95
    distill_max_new: int = 48
    # Phase 3: draft finetuning via white-box KD.
    finetune_steps: int = 1200
    n_checkpoints: int = 4  # evenly spaced ckpt1..ckpt4 (ckpt0 = base draft)
    distill_mix_ratio: float = 0.9  # 9:1 distillation:pretraining per batch
    # AdamW + warmup-decay (paper §A.3, scaled down).
    lr_max: float = 1e-3
    lr_min: float = 1e-5
    warmup_frac: float = 0.1
    weight_decay: float = 0.01
    grad_clip: float = 1.0


TRAIN_CONFIG = TrainConfig()

# AOT export block sizes (fixed shapes — PJRT executables are static).
PREFILL_BLOCK = 32
# Covers gamma+1 for gamma <= 5 (the paper sweeps {3, 5}). Was 8; shrinking
# to 6 cut verify latency ~12% since the executable always computes the
# full block (§Perf iteration 4).
VERIFY_BLOCK = 6
DECODE_BLOCK = 1

"""SynthChat — the synthetic language substrate.

The paper pretrains on a 600B-token English corpus and distills with seed
instructions from OIG-small-chip2 / OpenAssistant; none of that is usable at
CPU scale, so we build a stochastic language with the same *structure*:

- a ~512-token word vocabulary split into shared function words, topic
  content words (8 topics, "English" side) and a disjoint "German-like"
  vocabulary with a bijective word mapping (for the WMT-like OOD task);
- a first-order Markov topic grammar generating documents;
- four instruction task families mirroring the paper's evaluation suite:
    dolly  — open-ended generation about a topic,
    xsum   — extreme summarization (doc -> ~1 sentence of topic keywords),
    cnndm  — news summarization (longer doc -> multi-sentence summary),
    wmt    — translation de->en (OOD: excluded from distillation seeds).

Determinism: everything is driven by numpy Generators seeded explicitly, so
the corpus, the tasks and the vocab are reproducible bit-for-bit. The vocab
is exported to artifacts/vocab.json and re-implemented by the Rust
`tokenizer` + `workload` modules; python/tests/test_data.py pins hashes that
the Rust side property-tests against.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary layout (fixed, index-stable)
# ---------------------------------------------------------------------------

PAD, BOS, EOS, USER, ASST = 0, 1, 2, 3, 4
SPECIAL_TOKENS = ["<pad>", "<bos>", "<eos>", "<user>", "<asst>"]

N_TOPICS = 8
WORDS_PER_TOPIC = 28
N_FUNCTION_WORDS = 24
N_TEMPLATE_WORDS = 16
N_DE_WORDS = 96  # German-like, bijectively mapped onto the first EN words

_CONSONANTS = "bdfgklmnprstvz"
_VOWELS = "aeiou"


def _synth_word(rng: np.random.Generator, syllables: int) -> str:
    return "".join(
        _CONSONANTS[rng.integers(len(_CONSONANTS))] + _VOWELS[rng.integers(len(_VOWELS))]
        for _ in range(syllables)
    )


@dataclasses.dataclass
class Vocab:
    """Word-level vocabulary shared between python training and rust serving."""

    words: List[str]
    topic_ranges: List[Tuple[int, int]]  # [lo, hi) token-id range per topic
    function_range: Tuple[int, int]
    template_range: Tuple[int, int]
    de_range: Tuple[int, int]
    de_to_en: List[int]  # de token id -> en token id (bijective)

    @property
    def size(self) -> int:
        return len(self.words)

    def encode(self, text: str) -> List[int]:
        index = self._index()
        return [index[w] for w in text.split()]

    def decode(self, ids: Sequence[int]) -> str:
        return " ".join(self.words[i] for i in ids)

    def _index(self):
        if not hasattr(self, "_idx"):
            self._idx = {w: i for i, w in enumerate(self.words)}
        return self._idx

    def to_json(self) -> dict:
        return {
            "words": self.words,
            "topic_ranges": self.topic_ranges,
            "function_range": list(self.function_range),
            "template_range": list(self.template_range),
            "de_range": list(self.de_range),
            "de_to_en": self.de_to_en,
            "special": {"pad": PAD, "bos": BOS, "eos": EOS, "user": USER, "asst": ASST},
        }

    def content_hash(self) -> str:
        return hashlib.sha256(json.dumps(self.to_json(), sort_keys=True).encode()).hexdigest()[:16]


def build_vocab(seed: int = 7) -> Vocab:
    """Deterministically build the SynthChat vocabulary (size <= 512)."""
    rng = np.random.default_rng(seed)
    words = list(SPECIAL_TOKENS)
    seen = set(words)

    def add(n: int, syllables: int, prefix: str = "") -> Tuple[int, int]:
        lo = len(words)
        while len(words) < lo + n:
            w = prefix + _synth_word(rng, syllables)
            if w not in seen:
                seen.add(w)
                words.append(w)
        return (lo, lo + n)

    function_range = add(N_FUNCTION_WORDS, 1)
    template_range = add(N_TEMPLATE_WORDS, 2)
    topic_ranges = [add(WORDS_PER_TOPIC, 2) for _ in range(N_TOPICS)]
    de_range = add(N_DE_WORDS, 3, prefix="x")

    # de word k maps to the k-th English content word (topic words flattened).
    en_flat = [i for lo, hi in topic_ranges for i in range(lo, hi)]
    de_to_en = [en_flat[k % len(en_flat)] for k in range(N_DE_WORDS)]

    return Vocab(
        words=words,
        topic_ranges=topic_ranges,
        function_range=function_range,
        template_range=template_range,
        de_range=de_range,
        de_to_en=de_to_en,
    )


# ---------------------------------------------------------------------------
# Topic grammar: first-order Markov chains with shared function words
# ---------------------------------------------------------------------------


class TopicGrammar:
    """Per-topic Markov chain over (topic content words + function words).

    Transition matrices are themselves deterministic functions of the seed, so
    python and any re-implementation agree on the *distribution*; samples are
    reproducible given the generator state.
    """

    def __init__(self, vocab: Vocab, seed: int = 11):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.chains = []  # (token_ids, transition[ n, n ], init[ n ])
        flo, fhi = vocab.function_range
        func = list(range(flo, fhi))
        for t, (lo, hi) in enumerate(vocab.topic_ranges):
            ids = np.array(list(range(lo, hi)) + func, dtype=np.int64)
            n = len(ids)
            # Sparse-ish rows: concentrate mass on ~6 successors per word.
            trans = np.full((n, n), 1e-3)
            for i in range(n):
                succ = rng.choice(n, size=6, replace=False)
                trans[i, succ] += rng.dirichlet(np.ones(6)) * 1.0
            trans /= trans.sum(axis=1, keepdims=True)
            init = rng.dirichlet(np.ones(n) * 0.5)
            self.chains.append((ids, trans, init))

    def sample_sentence(self, rng: np.random.Generator, topic: int, length: int) -> List[int]:
        ids, trans, init = self.chains[topic]
        out = [int(rng.choice(len(ids), p=init))]
        for _ in range(length - 1):
            out.append(int(rng.choice(len(ids), p=trans[out[-1]])))
        return [int(ids[i]) for i in out]

    def topic_keywords(self, topic: int, k: int = 6) -> List[int]:
        """Deterministic 'summary' keywords: the k most likely initial words."""
        ids, _, init = self.chains[topic]
        order = np.argsort(-init)[:k]
        return [int(ids[i]) for i in order]


# ---------------------------------------------------------------------------
# Corpus + task generation
# ---------------------------------------------------------------------------

TASKS = ("dolly", "xsum", "cnndm", "wmt")


@dataclasses.dataclass
class Example:
    task: str
    prompt: List[int]  # [BOS] <user> ... <asst>
    response: List[int]  # reference response tokens (no EOS)
    topic: int


class SynthChat:
    """Corpus + instruction-task sampler over the SynthChat language."""

    def __init__(self, vocab: Optional[Vocab] = None, seed: int = 13):
        self.vocab = vocab or build_vocab()
        self.grammar = TopicGrammar(self.vocab, seed=seed)
        self._seed = seed
        # Template word ids used as fixed task markers.
        tlo, _ = self.vocab.template_range
        self.m_tell, self.m_about, self.m_sum, self.m_brief, self.m_news, self.m_trans = (
            tlo, tlo + 1, tlo + 2, tlo + 3, tlo + 4, tlo + 5
        )

    # -- pretraining corpus --------------------------------------------------

    def corpus_stream(self, seed: int, include_parallel: bool = True) -> Iterator[List[int]]:
        """Infinite stream of documents for next-token pretraining.

        Mixture: topic documents (70%), German-like documents (15%), parallel
        de<sep>en fragments (15%). The latter two give the *base* draft its
        translation competence — the ingredient behind the paper's Figure 3
        OOD inversion (finetuning on chat data erodes it).
        """
        rng = np.random.default_rng(seed)
        while True:
            u = rng.random()
            if u < 0.70 or not include_parallel:
                topic = int(rng.integers(N_TOPICS))
                doc: List[int] = []
                for _ in range(int(rng.integers(2, 6))):
                    doc += self.grammar.sample_sentence(rng, topic, int(rng.integers(6, 14)))
                yield doc + [EOS]
            elif u < 0.85:
                yield self._de_sentence(rng, int(rng.integers(5, 12))) + [EOS]
            else:
                de = self._de_sentence(rng, int(rng.integers(4, 9)))
                en = [self.vocab.de_to_en[t - self.vocab.de_range[0]] for t in de]
                yield de + [self.m_trans] + en + [EOS]

    def _de_sentence(self, rng: np.random.Generator, length: int) -> List[int]:
        lo, hi = self.vocab.de_range
        # Random-walk with locality so the 'language' has bigram structure.
        cur = int(rng.integers(lo, hi))
        out = [cur]
        for _ in range(length - 1):
            cur = lo + (cur - lo + int(rng.integers(1, 7))) % (hi - lo)
            out.append(cur)
        return out

    # -- instruction tasks ---------------------------------------------------

    def sample_example(self, rng: np.random.Generator, task: str) -> Example:
        topic = int(rng.integers(N_TOPICS))
        g = self.grammar
        if task == "dolly":
            kw = g.topic_keywords(topic, 2)
            instr = [self.m_tell, self.m_about] + kw
            resp = g.sample_sentence(rng, topic, int(rng.integers(16, 32)))
        elif task == "xsum":
            doc = []
            for _ in range(3):
                doc += g.sample_sentence(rng, topic, int(rng.integers(8, 14)))
            instr = [self.m_sum, self.m_brief] + doc
            resp = g.topic_keywords(topic, 6)
        elif task == "cnndm":
            doc = []
            for _ in range(5):
                doc += g.sample_sentence(rng, topic, int(rng.integers(8, 14)))
            instr = [self.m_news, self.m_sum] + doc
            resp = g.topic_keywords(topic, 6) + g.sample_sentence(rng, topic, 10)
        elif task == "wmt":
            de = self._de_sentence(rng, int(rng.integers(6, 12)))
            instr = [self.m_trans] + de
            resp = [self.vocab.de_to_en[t - self.vocab.de_range[0]] for t in de]
        else:
            raise ValueError(f"unknown task {task!r}")
        prompt = [BOS, USER] + instr + [ASST]
        return Example(task=task, prompt=prompt, response=resp, topic=topic)

    def sft_stream(self, seed: int, tasks: Sequence[str] = TASKS) -> Iterator[List[int]]:
        """Chat-SFT stream for the *target* model: prompt+reference response."""
        rng = np.random.default_rng(seed)
        while True:
            ex = self.sample_example(rng, tasks[int(rng.integers(len(tasks)))])
            yield ex.prompt + ex.response + [EOS]

    def seed_prompts(self, seed: int, n: int, tasks: Sequence[str]) -> List[Example]:
        """Distillation seed instructions (paper §2.2). `tasks` normally
        excludes 'wmt' — that is exactly what makes WMT OOD in Figure 3."""
        rng = np.random.default_rng(seed)
        return [self.sample_example(rng, tasks[i % len(tasks)]) for i in range(n)]


# ---------------------------------------------------------------------------
# `specd distill` shard reader (phase-2 data generated by the Rust stack)
# ---------------------------------------------------------------------------
#
# Layout mirror of rust/src/dataset.rs (little-endian):
#
#   manifest.json       metadata + per-shard FNV-1a-64 checksums
#   shard-NNNNN.spds    magic "SPDS1\0" | topk u16 | reserved u16 | records:
#     seq_index u64 | task_id u8 | temperature f32
#     prompt_len u32 | resp_len u32
#     prompt u32*prompt_len | response u32*resp_len
#     per response position (when topk > 0): ids u32*topk | logits f32*topk
#
# Captured logits are RAW (pre-temperature) target rows, descending, so the
# distillation loss can be computed against the true target distribution
# instead of the one-hot sampled token.

DISTILL_SHARD_MAGIC = b"SPDS1\x00"
DISTILL_FORMAT_TAG = "SPDD1"


def _fnv1a64(data: bytes) -> int:
    """FNV-1a 64 (inherently sequential, so pure Python — ~5 MB/s; fine
    for CPU-scale datasets, and `verify_checksums=False` skips it for
    repeated loads of an already-verified directory)."""
    h = 0xCBF29CE484222325
    mult, mask = 0x100000001B3, 0xFFFFFFFFFFFFFFFF
    for b in data:
        h = ((h ^ b) * mult) & mask
    return h


@dataclasses.dataclass
class DistillShardRecord:
    """One target-generated sequence from a `specd distill` shard."""

    seq_index: int
    task: str
    temperature: float
    prompt: List[int]
    response: List[int]
    topk_ids: Optional[np.ndarray]  # [resp_len, topk] int64, or None
    topk_logits: Optional[np.ndarray]  # [resp_len, topk] float32, or None

    @property
    def tokens(self) -> List[int]:
        return self.prompt + self.response


def load_distill_shards(dir_path: str, verify_checksums: bool = True) -> List[DistillShardRecord]:
    """Read a `specd distill` dataset directory (manifest + shards)."""
    with open(os.path.join(dir_path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != DISTILL_FORMAT_TAG:
        raise ValueError(f"not a {DISTILL_FORMAT_TAG} dataset: {dir_path}")
    topk = int(manifest["topk"])
    tasks = [m["task"] for m in manifest["mix"]]
    out: List[DistillShardRecord] = []
    for shard in manifest["shards"]:
        path = os.path.join(dir_path, shard["file"])
        with open(path, "rb") as f:
            raw = f.read()
        if len(raw) != int(shard["bytes"]):
            raise ValueError(f"{shard['file']}: size mismatch")
        if verify_checksums and _fnv1a64(raw) != int(shard["fnv64"], 16):
            raise ValueError(f"{shard['file']}: checksum mismatch")
        if raw[:6] != DISTILL_SHARD_MAGIC:
            raise ValueError(f"{shard['file']}: bad magic")
        (shard_topk,) = struct.unpack_from("<H", raw, 6)
        if shard_topk != topk:
            raise ValueError(f"{shard['file']}: topk {shard_topk} != manifest {topk}")
        pos = 10  # magic + topk + reserved
        n = 0
        while pos < len(raw):
            seq_index, task_id, temperature, prompt_len, resp_len = struct.unpack_from(
                "<QBfII", raw, pos
            )
            pos += 8 + 1 + 4 + 4 + 4
            prompt = np.frombuffer(raw, "<u4", prompt_len, pos).tolist()
            pos += 4 * prompt_len
            response = np.frombuffer(raw, "<u4", resp_len, pos).tolist()
            pos += 4 * resp_len
            topk_ids = topk_logits = None
            if topk > 0:
                # One structured read for the whole capture block (per
                # position: k ids then k logits).
                row_dt = np.dtype([("ids", "<u4", (topk,)), ("logits", "<f4", (topk,))])
                rows = np.frombuffer(raw, row_dt, resp_len, pos)
                pos += row_dt.itemsize * resp_len
                topk_ids = rows["ids"].astype(np.int64)
                topk_logits = np.ascontiguousarray(rows["logits"])
            out.append(
                DistillShardRecord(
                    seq_index=seq_index,
                    task=tasks[task_id],
                    temperature=temperature,
                    prompt=prompt,
                    response=response,
                    topk_ids=topk_ids,
                    topk_logits=topk_logits,
                )
            )
            n += 1
        if n != int(shard["records"]):
            raise ValueError(f"{shard['file']}: {n} records, manifest says {shard['records']}")
    if len(out) != int(manifest["records_total"]):
        raise ValueError("records_total mismatch across shards")
    for i, rec in enumerate(out):
        if rec.seq_index != i:
            raise ValueError(f"non-contiguous seq_index at {i}")
    return out


def distill_set_from_records(records: Sequence[DistillShardRecord]) -> List[Tuple[List[int], int]]:
    """Adapt shard records to the [(tokens, prompt_len)] structure that
    train.py's phase-3 finetuning consumes (see build_distill_dataset)."""
    return [(rec.tokens, len(rec.prompt)) for rec in records]


def distill_set_from_shards(dir_path: str) -> List[Tuple[List[int], int]]:
    """distill_set_from_records over a whole shard directory."""
    return distill_set_from_records(load_distill_shards(dir_path))


def pack_stream(stream: Iterator[List[int]], seq_len: int) -> Iterator[np.ndarray]:
    """Concatenate documents into fixed-length chunks (paper §A.4: sequences
    concatenated into 2048-token chunks, no padding)."""
    buf: List[int] = []
    for doc in stream:
        buf.extend(doc)
        while len(buf) >= seq_len + 1:
            yield np.array(buf[: seq_len + 1], dtype=np.int32)
            buf = buf[seq_len:]


def batch_stream(stream: Iterator[List[int]], seq_len: int, batch: int) -> Iterator[np.ndarray]:
    packed = pack_stream(stream, seq_len)
    while True:
        yield np.stack([next(packed) for _ in range(batch)])

"""L2 — Llama-2-family decoder-only transformer in JAX.

Two forward paths over the same parameters:

- `forward_train(params, cfg, tokens[B, T])` — batched, no KV cache, causal
  mask; used by all three training phases. Runs the pure-jnp reference ops
  (kernels/ref.py) for speed on CPU.
- `forward_cached(params, cfg, tokens[T], kv, pos)` — single-sequence,
  fixed-capacity KV cache, *position-masked* attention; this is the function
  AOT-exported to HLO for the Rust runtime (prefill / decode / verify entry
  points differ only in T). With use_pallas=True the attention / rmsnorm /
  swiglu bodies are the L1 Pallas kernels, so the exported HLO is lowered
  through the kernel path. Tests pin the two paths equal.

Parameters live in a *flat dict* with lexicographically sortable keys so the
AOT export, the weights file and the Rust loader all agree on one canonical
ordering (see aot.py manifest).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels import attention as k_attention
from .kernels import ref
from .kernels import rmsnorm as k_rmsnorm
from .kernels import swiglu as k_swiglu

Params = Dict[str, jax.Array]


def param_names(cfg: ModelConfig):
    """Canonical (sorted) parameter name list for this config."""
    names = ["embed", "final_norm"]
    if not cfg.tie_embeddings:
        names.append("unembed")
    for l in range(cfg.n_layers):
        for p in ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w1", "w3", "w2"):
            names.append(f"layer{l:02d}.{p}")
    return sorted(names)


def param_shape(cfg: ModelConfig, name: str) -> Tuple[int, ...]:
    h, i, v = cfg.hidden, cfg.intermediate, cfg.vocab_size
    if name == "embed":
        return (v, h)
    if name == "unembed":
        return (h, v)
    if name == "final_norm":
        return (h,)
    base = name.split(".")[1]
    return {
        "attn_norm": (h,),
        "mlp_norm": (h,),
        "wq": (h, h),
        "wk": (h, h),
        "wv": (h, h),
        "wo": (h, h),
        "w1": (h, i),
        "w3": (h, i),
        "w2": (i, h),
    }[base]


def init_params(cfg: ModelConfig, seed: int) -> Params:
    """Deterministic scaled-normal init (norm gains at 1)."""
    rng = np.random.default_rng(seed)
    params: Params = {}
    for name in param_names(cfg):
        shape = param_shape(cfg, name)
        if name.endswith("norm"):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else cfg.hidden
            std = 1.0 / np.sqrt(fan_in)
            if name.split(".")[-1] in ("wo", "w2"):  # residual-branch scaling
                std /= np.sqrt(2.0 * cfg.n_layers)
            arr = rng.normal(0.0, std, shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def count_params(params: Params) -> int:
    return int(sum(int(np.prod(p.shape)) for p in params.values()))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, half-split (NeoX) convention.

    x: [..., T, H, D]; positions: [T] absolute positions.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[..., None, :]  # [T, 1, half] broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unembed(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["unembed"]


# ---------------------------------------------------------------------------
# Training path (batched, no cache)
# ---------------------------------------------------------------------------


def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """tokens: [B, T] int32 -> logits [B, T, V]. Causal, from position 0."""
    b, t = tokens.shape
    pos = jnp.arange(t)
    x = params["embed"][tokens]  # [B, T, H]
    mask = jnp.tril(jnp.ones((t, t), bool))
    scale = 1.0 / jnp.sqrt(jnp.array(cfg.head_dim, jnp.float32))
    for l in range(cfg.n_layers):
        pre = f"layer{l:02d}."
        xn = ref.rmsnorm(x, params[pre + "attn_norm"], cfg.norm_eps)
        q = (xn @ params[pre + "wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (xn @ params[pre + "wk"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (xn @ params[pre + "wv"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        logits = jnp.einsum("bthd,bshd->bhts", q, k) * scale
        logits = jnp.where(mask[None, None], logits, ref.NEG_INF)
        att = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", att, v).reshape(b, t, cfg.hidden)
        x = x + o @ params[pre + "wo"]
        xn = ref.rmsnorm(x, params[pre + "mlp_norm"], cfg.norm_eps)
        x = x + ref.swiglu(xn, params[pre + "w1"], params[pre + "w3"], params[pre + "w2"])
    x = ref.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x)


# ---------------------------------------------------------------------------
# Serving path (single sequence, KV cache, position-masked) — AOT-exported
# ---------------------------------------------------------------------------


def init_kv(cfg: ModelConfig) -> jax.Array:
    """KV cache buffer [L, 2, S, heads, head_dim], zeros."""
    return jnp.zeros(
        (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32
    )


def forward_cached(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # [T] int32
    kv: jax.Array,  # [L, 2, S, heads, head_dim]
    pos: jax.Array,  # scalar int32: absolute position of tokens[0]
    use_pallas: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [T, V], updated kv).

    Rows pos..pos+T-1 of the cache are overwritten; attention sees exactly
    rows <= query position (stale higher rows are invisible), which is what
    lets the Rust coordinator roll back speculation by decrementing a length.
    """
    t = tokens.shape[0]
    positions = pos + jnp.arange(t)
    x = params["embed"][tokens]  # [T, H]

    rms = k_rmsnorm.rmsnorm if use_pallas else (lambda a, w: ref.rmsnorm(a, w, cfg.norm_eps))
    mlp = k_swiglu.swiglu if use_pallas else ref.swiglu
    attn = k_attention.attention if use_pallas else ref.attention

    for l in range(cfg.n_layers):
        pre = f"layer{l:02d}."
        xn = rms(x, params[pre + "attn_norm"])
        q = (xn @ params[pre + "wq"]).reshape(t, cfg.n_heads, cfg.head_dim)
        k = (xn @ params[pre + "wk"]).reshape(t, cfg.n_heads, cfg.head_dim)
        v = (xn @ params[pre + "wv"]).reshape(t, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kv = jax.lax.dynamic_update_slice(kv, k[None, None], (l, 0, pos, 0, 0))
        kv = jax.lax.dynamic_update_slice(kv, v[None, None], (l, 1, pos, 0, 0))
        o = attn(q, kv[l, 0], kv[l, 1], pos)  # [T, heads, head_dim]
        x = x + o.reshape(t, cfg.hidden) @ params[pre + "wo"]
        xn = rms(x, params[pre + "mlp_norm"])
        x = x + mlp(xn, params[pre + "w1"], params[pre + "w3"], params[pre + "w2"])
    x = rms(x, params["final_norm"])
    return _unembed(params, cfg, x), kv

"""AOT export: lower the L2/L1 stack to HLO text + pack weights for Rust.

Interchange contract with the Rust runtime (rust/src/runtime, rust/src/weights):

- HLO **text**, not serialized HloModuleProto: jax >= 0.5 emits protos with
  64-bit instruction ids that the crate's xla_extension 0.5.1 rejects; the
  text parser reassigns ids (see /opt/xla-example/README.md).
- Weights are *runtime arguments*, not HLO constants. Consequence: every
  draft variant (base + 3 losses x 4 checkpoints) shares ONE compiled
  executable per entry point; swapping models is swapping device buffers.
- Three entry points per architecture, all instances of
  forward_cached(params, kv, tokens[T], pos) -> (logits[T, V], kv'):
      prefill  T = 32   (prompt ingestion, chunked)
      verify   T = 8    (target-side scoring of gamma+1 <= 8 tokens)
      decode   T = 1    (draft autoregression + AR baseline)
  Argument order = sorted parameter names, then kv, tokens, pos — recorded
  in manifest.json and asserted by the Rust loader.
- Batched `[B, T]` entry points (optional, `--batch-sizes`): each single
  entry also exports `fn(params.., states[B, state_len], tokens[B, T],
  pos[B], active_mask[B]) -> states'[B, state_len]` as
  `<entry>.b<B>.hlo.txt`, plus a batched logits extractor and a `pack`
  entry (write one state vector over one arena lane). Masked lanes pass
  through bit-for-bit, so a partially full batch is correct; the Rust
  scheduler uses these to issue ONE dispatch per lockstep phase instead of
  one per sequence. Manifest key `arch.*.batch_sizes` lists what was
  exported; old bundles lack it and the runtime serves per-lane.
- weights .bin format "SPCD1": per tensor, name + dims + raw f32 LE bytes.
- golden.json: input/output probes for every exported (model, entry) pair so
  the Rust integration tests can pin end-to-end numerics bit-for-bit-ish
  (1e-4 tolerance; CPU PJRT on both sides).

Run: cd python && python -m compile.aot --train-dir ../artifacts/train --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import (DECODE_BLOCK, DRAFT_CONFIG, PREFILL_BLOCK, TARGET_CONFIG,
                     VERIFY_BLOCK, ModelConfig)
from .data import TASKS, SynthChat, build_vocab

ENTRY_POINTS = {"prefill": PREFILL_BLOCK, "verify": VERIFY_BLOCK, "decode": DECODE_BLOCK}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    return_tuple=False: every entry point returns exactly ONE array (the
    state vector), so PJRT hands back a plain (non-tuple) device buffer that
    can be fed straight into the next execute_b call — the KV cache never
    crosses the device boundary (see `state layout` below).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def kv_len(cfg: ModelConfig) -> int:
    return cfg.n_layers * 2 * cfg.max_seq * cfg.n_heads * cfg.head_dim


def state_len(cfg: ModelConfig) -> int:
    """State layout: [ kv (kv_len) | logits region (PREFILL_BLOCK * V) ].

    All three entry points share this shape so a sequence's device buffer
    threads through prefill -> decode/verify without reshaping. An entry
    with block T writes its [T, V] logits at offset kv_len; the Rust side
    reads exactly that slice via copy_raw_to_host_sync(offset=kv_len).
    """
    return kv_len(cfg) + PREFILL_BLOCK * cfg.vocab_size


def state_fn(cfg: ModelConfig, block: int, use_pallas: bool = True):
    """The single-sequence state-vector function all entry points lower.

    `fn(flat_params, state[state_len], tokens[block], pos) -> state'` with
    the [ kv | logits | tail ] layout described in `state_len`. Shared by
    the single-sequence entries (lowered directly) and the batched entries
    (lowered under `jax.vmap`)."""
    names = model.param_names(cfg)
    kvn = kv_len(cfg)

    def fn(flat_params: List[jax.Array], state, tokens, pos):
        params = dict(zip(names, flat_params))
        kv = state[:kvn].reshape(
            (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        )
        logits, kv2 = model.forward_cached(params, cfg, tokens, kv, pos, use_pallas=use_pallas)
        tail = state[kvn + block * cfg.vocab_size :]
        return jnp.concatenate([kv2.reshape(-1), logits.reshape(-1), tail])

    return fn


def param_specs(cfg: ModelConfig) -> List[jax.ShapeDtypeStruct]:
    return [
        jax.ShapeDtypeStruct(model.param_shape(cfg, n), jnp.float32)
        for n in model.param_names(cfg)
    ]


def lower_entry(cfg: ModelConfig, block: int, use_pallas: bool = True) -> str:
    """Lower forward_cached at a fixed block size to HLO text."""
    fn = state_fn(cfg, block, use_pallas)
    state_spec = jax.ShapeDtypeStruct((state_len(cfg),), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((block,), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    # NOT donated: input-output aliasing survives the HLO-text roundtrip
    # (`input_output_alias=...`), but measured 15-40% SLOWER on the TFRT CPU
    # client — the Rust side's buffer handle keeps a reference alive, so
    # PJRT copies defensively on every donated call. See EXPERIMENTS.md
    # §Perf iteration log.
    lowered = jax.jit(fn).lower(param_specs(cfg), state_spec, tok_spec, pos_spec)
    return to_hlo_text(lowered)


def batched_fn(cfg: ModelConfig, block: int, use_pallas: bool = True):
    """The batched state function the `[B, T]` entry points lower.

    `fn(flat_params, states[B, state_len], tokens[B, block], pos[B],
    active_mask[B]) -> states'[B, state_len]`. Weights are broadcast;
    lanes with `active_mask == 0` pass their state through bit-for-bit
    (a `where` on the vmapped output), so a partially full batch is
    correct and one dispatch advances every active lane.

    Ragged-wave mask semantics (batched admission prefill): `pos` is
    PER-LANE, so one dispatch may advance lanes sitting at different
    sequence positions — a wave of mixed-length prompts chunk-locksteps
    with every lane at `pos = chunk_start` until its own prompt runs out,
    after which the lane is masked and its state (final-chunk logits rows
    included) passes through untouched for the rest of the wave. Masked
    lanes therefore keep their last-written logits readable until their
    next dispatch, which is what lets the Rust side read every wave
    member's last-row logits once, after the final chunk
    (`golden_probe_prefill_wave` pins this contract)."""
    one = state_fn(cfg, block, use_pallas)

    def fn(flat_params: List[jax.Array], states, tokens, pos, mask):
        new = jax.vmap(lambda s, t, p: one(flat_params, s, t, p))(states, tokens, pos)
        return jnp.where((mask != 0)[:, None], new, states)

    return fn


def lower_entry_batched(cfg: ModelConfig, block: int, batch: int,
                        use_pallas: bool = True) -> str:
    """Lower the batched `[B, T]` variant of one entry point to HLO text.

    One PJRT dispatch of this executable replaces `batch` single-sequence
    dispatches: the Rust scheduler packs every active lane's state into a
    device-resident `[B, state_len]` arena and runs each lockstep phase as
    a single call (rust/src/runtime.rs `StateArena`)."""
    fn = batched_fn(cfg, block, use_pallas)
    lowered = jax.jit(fn).lower(
        param_specs(cfg),
        jax.ShapeDtypeStruct((batch, state_len(cfg)), jnp.float32),
        jax.ShapeDtypeStruct((batch, block), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_extract(cfg: ModelConfig) -> str:
    """Logits-extraction entry: `fn(state) -> logits_region`.

    The TFRT CPU PJRT client implements neither partial raw reads nor cheap
    literal slicing, so reading logits out of a step's output would cost a
    full state-sized device->host copy (1.6MB for the target, per call).
    Instead this 2-op executable slices the [PREFILL_BLOCK * V] logits
    region on device; the host then downloads only ~48KB. §Perf iteration 2
    in EXPERIMENTS.md: -24% target decode latency.
    """
    kvn = kv_len(cfg)
    n = PREFILL_BLOCK * cfg.vocab_size

    def fn(state):
        return jax.lax.dynamic_slice(state, (kvn,), (n,))

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((state_len(cfg),), jnp.float32))
    return to_hlo_text(lowered)


def lower_extract_batched(cfg: ModelConfig, batch: int) -> str:
    """Batched logits slicer: `fn(states[B, S]) -> logits[B, extract_len]`.

    After one batched dispatch the host needs every active lane's logits;
    this downloads `B * PREFILL_BLOCK * V` floats in one readback instead
    of B full-state copies (the batched analogue of `lower_extract`)."""
    kvn = kv_len(cfg)
    n = PREFILL_BLOCK * cfg.vocab_size

    def fn(states):
        return jax.lax.slice(states, (0, kvn), (batch, kvn + n))

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, state_len(cfg)), jnp.float32)
    )
    return to_hlo_text(lowered)


def lower_pack(cfg: ModelConfig, batch: int) -> str:
    """Lane-pack entry: `fn(states[B, S], incoming[S], lane[]) -> states'`.

    Writes one sequence's full state vector over lane `lane` of the arena
    (admission gather). Because the entire row is overwritten, recycled
    lanes need no zeroing — whatever the previous occupant left is dead."""
    def fn(states, incoming, lane):
        return jax.lax.dynamic_update_slice(states, incoming[None, :], (lane, 0))

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, state_len(cfg)), jnp.float32),
        jax.ShapeDtypeStruct((state_len(cfg),), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Weights binary format ("SPCD1")
# ---------------------------------------------------------------------------

MAGIC = b"SPCD1\x00"


def write_weights(path: str, params: Dict[str, np.ndarray]) -> None:
    """Canonical order = sorted names (must match lower_entry's flat order)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        names = sorted(params.keys())
        f.write(struct.pack("<I", len(names)))
        for name in names:
            arr = np.ascontiguousarray(np.asarray(params[name], dtype=np.float32))
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))


def load_npz(path: str) -> Dict[str, np.ndarray]:
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


# ---------------------------------------------------------------------------
# Golden probes for the Rust integration tests
# ---------------------------------------------------------------------------


def golden_probe(cfg: ModelConfig, params: Dict[str, np.ndarray], entry: str, block: int):
    """Deterministic probe: fixed tokens/pos through the pallas path."""
    rng = np.random.default_rng(42)
    names = model.param_names(cfg)
    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    kv = model.init_kv(cfg)
    tokens = jnp.asarray(rng.integers(5, cfg.vocab_size, size=block).astype(np.int32))
    pos = jnp.asarray(0, jnp.int32)
    logits, kv2 = model.forward_cached(jparams, cfg, tokens, kv, pos, use_pallas=True)
    # Second call continuing at pos=block exercises cache reuse.
    tokens2 = jnp.asarray(rng.integers(5, cfg.vocab_size, size=block).astype(np.int32))
    logits2, _ = model.forward_cached(jparams, cfg, tokens2, kv2, jnp.asarray(block, jnp.int32),
                                      use_pallas=True)
    return {
        "entry": entry,
        "tokens": np.asarray(tokens).tolist(),
        "tokens2": np.asarray(tokens2).tolist(),
        # Store a slice of each logits row (full rows would bloat the file).
        "logits_head": np.asarray(logits[:, :8]).round(5).tolist(),
        "logits2_head": np.asarray(logits2[:, :8]).round(5).tolist(),
        "logits_last_argmax": int(np.argmax(np.asarray(logits)[-1])),
        "logits2_last_argmax": int(np.argmax(np.asarray(logits2)[-1])),
    }


def golden_probe_batched(cfg: ModelConfig, params: Dict[str, np.ndarray],
                         batch: int, block: int, rtol: float = 1e-5):
    """Self-checking probe for one batched entry at batch size `batch`.

    Runs the batched function over a half-masked batch (lane 1 inactive)
    of fresh zero states, asserts every active lane's output equals the
    single-sequence path and the masked lane's state passes through
    bit-for-bit, then records per-lane logits heads/argmaxes for the Rust
    integration test to pin against the compiled batched executable."""
    rng = np.random.default_rng(47)
    names = model.param_names(cfg)
    flat = [jnp.asarray(params[n]) for n in names]
    kvn = kv_len(cfg)
    v = cfg.vocab_size

    states = jnp.zeros((batch, state_len(cfg)), jnp.float32)
    tokens = jnp.asarray(rng.integers(5, v, size=(batch, block)).astype(np.int32))
    pos = jnp.zeros((batch,), jnp.int32)
    mask_np = np.ones(batch, np.int32)
    if batch > 1:
        mask_np[1] = 0  # pin the masked-lane no-op
    mask = jnp.asarray(mask_np)

    out = np.asarray(batched_fn(cfg, block)(flat, states, tokens, pos, mask))
    single = state_fn(cfg, block)
    heads, argmaxes = [], []
    for b in range(batch):
        if mask_np[b]:
            want = np.asarray(single(flat, states[b], tokens[b], pos[b]))
            np.testing.assert_allclose(out[b], want, rtol=rtol, atol=1e-5,
                                       err_msg=f"batched lane {b} != single path")
        else:
            np.testing.assert_array_equal(out[b], np.asarray(states[b]),
                                          err_msg="masked lane must be a no-op")
        rows = out[b, kvn:kvn + block * v].reshape(block, v)
        heads.append(rows[:, :8].round(5).tolist())
        argmaxes.append(int(np.argmax(rows[-1])))
    return {
        "batch": batch,
        "block": block,
        "tokens": np.asarray(tokens).tolist(),
        "mask": mask_np.tolist(),
        "logits_head": heads,
        "logits_last_argmax": argmaxes,
    }


def golden_probe_prefill_wave(cfg: ModelConfig, params: Dict[str, np.ndarray],
                              batch: int, block: int, rtol: float = 1e-5):
    """Self-checking probe for RAGGED batched admission-wave prefill.

    Chunk-locksteps a wave of mixed-length prompts — a single-token
    prompt, a multi-chunk prompt, an exact-boundary prompt and a short
    one — through `batched_fn` with per-lane pos/active_mask: a lane goes
    inactive once its prompt is exhausted and its state must pass through
    bit-for-bit until the wave drains, in exactly ceil(L_max/block)
    dispatches. Asserts every lane's final state equals sequential
    single-lane chunked prefill of its own prompt and that
    never-dispatched lanes stay zero, then records per-lane last-row
    logits heads/argmaxes for the Rust integration tests to pin against
    the compiled batched prefill executable."""
    assert batch >= 1 and block >= 1
    rng = np.random.default_rng(53)
    names = model.param_names(cfg)
    flat = [jnp.asarray(params[n]) for n in names]
    kvn = kv_len(cfg)
    v = cfg.vocab_size
    # Ragged lengths, clipped to the batch; extra lanes beyond them sit
    # idle for the whole wave (pinning the all-masked pass-through).
    lens = [L for L in (1, 2 * block + 3, block, max(2, block // 2)) if L <= cfg.max_seq]
    lens = lens[:batch]
    prompts = [rng.integers(5, v, size=L).astype(np.int32) for L in lens]

    fn = batched_fn(cfg, block)
    states = jnp.zeros((batch, state_len(cfg)), jnp.float32)
    max_len = max(lens)
    dispatches = 0
    for start in range(0, max_len, block):
        tokens = np.zeros((batch, block), np.int32)
        pos = np.zeros(batch, np.int32)
        mask = np.zeros(batch, np.int32)
        for b, (length, p) in enumerate(zip(lens, prompts)):
            if length > start:
                chunk = p[start:min(start + block, length)]
                tokens[b, :len(chunk)] = chunk
                pos[b] = start
                mask[b] = 1
        states = fn(flat, states, jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(mask))
        dispatches += 1
    assert dispatches == -(-max_len // block), "wave cost must be ceil(L_max/block)"
    states = np.asarray(states)

    single = state_fn(cfg, block)
    heads, argmaxes = [], []
    for b, (length, p) in enumerate(zip(lens, prompts)):
        # Sequential single-lane chunked prefill of the same prompt.
        want = jnp.zeros(state_len(cfg), jnp.float32)
        for start in range(0, length, block):
            chunk = p[start:min(start + block, length)]
            padded = np.zeros(block, np.int32)
            padded[:len(chunk)] = chunk
            want = single(flat, want, jnp.asarray(padded), jnp.asarray(start, jnp.int32))
        np.testing.assert_allclose(
            states[b], np.asarray(want), rtol=rtol, atol=1e-5,
            err_msg=f"wave lane {b} (len {length}) != sequential chunked prefill")
        last_row = (length - 1) % block
        rows = states[b, kvn:kvn + block * v].reshape(block, v)
        heads.append(rows[last_row, :8].round(5).tolist())
        argmaxes.append(int(np.argmax(rows[last_row])))
    for b in range(len(lens), batch):
        np.testing.assert_array_equal(
            states[b], np.zeros(state_len(cfg), np.float32),
            err_msg="never-dispatched lane must stay a zero state")
    return {
        "batch": batch,
        "block": block,
        "lens": lens,
        "prompts": [p.tolist() for p in prompts],
        "last_row_head": heads,
        "last_row_argmax": argmaxes,
    }


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def export_eval_prompts(out_dir: str, per_task: int = 48, seed: int = 20240601) -> None:
    """Evaluation prompt sets for the Rust benches (Figures 1-3).

    Drawn from the same SynthChat task distributions as training/distillation
    but with a held-out seed, so the Rust evaluator measures the exact task
    families the paper evaluates (dolly/xsum/cnndm + the OOD wmt task)."""
    synth = SynthChat()
    out = {}
    for task in TASKS:
        exs = synth.seed_prompts(seed + hash(task) % 1000, per_task, (task,))
        out[task] = [
            {"prompt": ex.prompt, "reference": ex.response, "topic": ex.topic}
            for ex in exs
        ]
    with open(os.path.join(out_dir, "eval_prompts.json"), "w") as f:
        json.dump(out, f)
    print(f"[aot] eval prompts: {per_task}/task x {len(TASKS)} tasks", flush=True)


DEFAULT_BATCH_SIZES = (8,)


def export(train_dir: str, out_dir: str, batch_sizes=DEFAULT_BATCH_SIZES) -> None:
    batch_sizes = sorted(set(int(b) for b in batch_sizes if int(b) > 1))
    os.makedirs(out_dir, exist_ok=True)
    vocab = build_vocab()
    with open(os.path.join(out_dir, "vocab.json"), "w") as f:
        json.dump(vocab.to_json(), f)
    export_eval_prompts(out_dir)

    # --- HLO per architecture (shared across weight variants) -------------
    for cfg in (TARGET_CONFIG, DRAFT_CONFIG):
        hlo_dir = os.path.join(out_dir, "hlo", cfg.name)
        os.makedirs(hlo_dir, exist_ok=True)
        for entry, block in ENTRY_POINTS.items():
            path = os.path.join(hlo_dir, f"{entry}.hlo.txt")
            print(f"[aot] lowering {cfg.name}/{entry} (T={block})", flush=True)
            text = lower_entry(cfg, block)
            with open(path, "w") as f:
                f.write(text)
        with open(os.path.join(hlo_dir, "extract.hlo.txt"), "w") as f:
            f.write(lower_extract(cfg))
        # Batched [B, T] entry points (one PJRT dispatch per lockstep
        # phase). File naming: <entry>.b<B>.hlo.txt — old bundles simply
        # lack these files and the Rust runtime falls back to per-lane
        # dispatch.
        for b in batch_sizes:
            for entry, block in ENTRY_POINTS.items():
                path = os.path.join(hlo_dir, f"{entry}.b{b}.hlo.txt")
                print(f"[aot] lowering {cfg.name}/{entry} (B={b}, T={block})", flush=True)
                with open(path, "w") as f:
                    f.write(lower_entry_batched(cfg, block, b))
            with open(os.path.join(hlo_dir, f"extract.b{b}.hlo.txt"), "w") as f:
                f.write(lower_extract_batched(cfg, b))
            with open(os.path.join(hlo_dir, f"pack.b{b}.hlo.txt"), "w") as f:
                f.write(lower_pack(cfg, b))

    # --- weights + golden probes per trained model -------------------------
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    models = {}
    golden = {}
    train_meta_path = os.path.join(train_dir, "meta.json")
    train_meta = json.load(open(train_meta_path)) if os.path.exists(train_meta_path) else {}
    for fname in sorted(os.listdir(train_dir)):
        if not fname.endswith(".npz"):
            continue
        name = fname[:-4]
        cfg = TARGET_CONFIG if name == "target" else DRAFT_CONFIG
        params = load_npz(os.path.join(train_dir, fname))
        write_weights(os.path.join(wdir, f"{name}.bin"), params)
        models[name] = {
            "arch": cfg.name,
            "weights": f"weights/{name}.bin",
            "params": int(sum(int(np.prod(v.shape)) for v in params.values())),
        }
        golden[name] = golden_probe(cfg, params, "verify", VERIFY_BLOCK)
        # Batched probes are self-checking (batched == per-lane asserted at
        # export time) and recorded per batch size for the Rust runtime test.
        golden[name]["batched"] = {
            str(b): golden_probe_batched(cfg, params, b, VERIFY_BLOCK)
            for b in batch_sizes
        }
        # Ragged admission-wave prefill probe (mask semantics for mixed
        # prompt lengths), likewise self-checking at export time.
        golden[name]["prefill_wave"] = {
            str(b): golden_probe_prefill_wave(cfg, params, b, PREFILL_BLOCK)
            for b in batch_sizes
        }
        print(f"[aot] packed {name} ({models[name]['params']} params)", flush=True)

    n_target = models["target"]["params"]
    for name, m in models.items():
        m["c_ratio"] = m["params"] / n_target

    manifest = {
        "format": "specd-artifacts-v1",
        "vocab": {"file": "vocab.json", "size": TARGET_CONFIG.vocab_size,
                  "hash": vocab.content_hash()},
        "entry_points": {k: {"block": v} for k, v in ENTRY_POINTS.items()},
        "arch": {
            cfg.name: {
                "hlo_dir": f"hlo/{cfg.name}",
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "hidden": cfg.hidden,
                "intermediate": cfg.intermediate,
                "head_dim": cfg.head_dim,
                "max_seq": cfg.max_seq,
                "vocab_size": cfg.vocab_size,
                "kv_len": kv_len(cfg),
                "state_len": state_len(cfg),
                "param_order": model.param_names(cfg),
                # Batched entry points exported for these batch sizes as
                # <entry>.b<B>.hlo.txt (+ extract.b<B> / pack.b<B>). Absent
                # or empty on older bundles: the Rust loader treats the key
                # as optional and serves per-lane.
                "batch_sizes": batch_sizes,
            }
            for cfg in (TARGET_CONFIG, DRAFT_CONFIG)
        },
        "models": models,
        "train_meta": train_meta,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"[aot] manifest with {len(models)} models -> {out_dir}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--train-dir", default="../artifacts/train")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch-sizes", default=",".join(str(b) for b in DEFAULT_BATCH_SIZES),
                    help="comma-separated [B, T] entry-point batch sizes ('' disables)")
    args = ap.parse_args()
    sizes = [int(b) for b in args.batch_sizes.split(",") if b.strip()]
    export(args.train_dir, args.out, batch_sizes=sizes)


if __name__ == "__main__":
    main()

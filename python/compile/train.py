"""The paper's three-phase draft training pipeline (§2, §A.3), build-time.

Phase 1  pretraining           — target AND draft pretrained on the SynthChat
                                 corpus with next-token loss; the target is
                                 then chat-SFT'd on instruction tasks so it
                                 plays the role of "Llama 2 Chat" (a chat-
                                 fine-tuned target whose SFT data the draft
                                 trainer is NOT allowed to reuse).
Phase 2  distillation dataset  — seed instructions (dolly/xsum/cnndm; wmt is
                                 deliberately excluded => Figure 3 OOD) are
                                 answered BY THE TARGET at temperatures
                                 {0, 0.3, 0.7, 1.0}, top-p 0.95 (§3).
Phase 3  finetune via KD       — white-box distillation of the draft on the
                                 phase-2 set, mixed 9:1 with pretraining
                                 chunks, one run per loss in {KLD, TVD,
                                 TVD++}, with evenly spaced checkpoints for
                                 the Figure 2 sweep.

Run:  cd python && python -m compile.train --out ../artifacts/train [--profile smoke]
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import losses, model, optim
from .config import DRAFT_CONFIG, TARGET_CONFIG, TRAIN_CONFIG, ModelConfig, TrainConfig
from .data import ASST, BOS, EOS, USER, Example, SynthChat, batch_stream

# ---------------------------------------------------------------------------
# Generic next-token training loop (phase 1 + target SFT)
# ---------------------------------------------------------------------------


def make_pretrain_step(cfg: ModelConfig, tc: TrainConfig, total_steps: int):
    warmup = max(1, int(tc.warmup_frac * total_steps))

    @jax.jit
    def step(params, opt_state, chunk):
        """chunk: [B, T+1] int32; next-token loss over all positions."""
        inputs, labels = chunk[:, :-1], chunk[:, 1:]
        weights = (labels != data_mod.PAD).astype(jnp.float32)

        def loss_fn(p):
            logits = model.forward_train(p, cfg, inputs)
            return losses.next_token_loss(logits, labels, weights)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = optim.warmup_decay_lr(opt_state["step"], total_steps, tc.lr_max, tc.lr_min, warmup)
        params, opt_state = optim.adamw_update(
            params, grads, opt_state, lr=lr,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip,
        )
        return params, opt_state, loss

    return step


def train_next_token(params, cfg: ModelConfig, tc: TrainConfig, stream, steps: int, tag: str):
    opt_state = optim.adamw_init(params)
    step_fn = make_pretrain_step(cfg, tc, steps)
    batches = batch_stream(stream, tc.seq_len, tc.batch_size)
    t0 = time.time()
    loss = float("nan")
    for i in range(steps):
        params, opt_state, loss = step_fn(params, opt_state, jnp.asarray(next(batches)))
        if i % 100 == 0 or i == steps - 1:
            print(f"[{tag}] step {i:5d}/{steps} loss={float(loss):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    return params, float(loss)


# ---------------------------------------------------------------------------
# Batched KV-cache generation (phase 2): vmap over sequences
# ---------------------------------------------------------------------------


def _batched_cached_forward(cfg: ModelConfig):
    def fwd(params, tokens, kv, pos):
        return model.forward_cached(params, cfg, tokens, kv, pos, use_pallas=False)

    return jax.jit(jax.vmap(fwd, in_axes=(None, 0, 0, 0)))


def _top_p_sample(rng: np.random.Generator, probs: np.ndarray, top_p: float) -> np.ndarray:
    """Nucleus sampling, rowwise. probs: [B, V] -> tokens [B]."""
    out = np.empty(probs.shape[0], np.int64)
    for b in range(probs.shape[0]):
        order = np.argsort(-probs[b])
        csum = np.cumsum(probs[b][order])
        keep = csum - probs[b][order] < top_p  # always keeps the top token
        p = np.where(keep, probs[b][order], 0.0)
        p /= p.sum()
        out[b] = order[rng.choice(len(p), p=p)]
    return out


def generate_batch(
    params,
    cfg: ModelConfig,
    prompts: List[List[int]],
    max_new: int,
    temperature: float,
    top_p: float,
    seed: int,
) -> List[List[int]]:
    """Autoregressive batched generation with per-sequence KV caches.

    Right-padded prefill writes garbage K/V rows beyond each prompt's length,
    but those rows sit at positions > the sequence's current length and the
    position-masked attention never sees them before they are overwritten —
    the same invariant the Rust KV manager relies on.
    """
    rng = np.random.default_rng(seed)
    fwd = _batched_cached_forward(cfg)
    bsz = len(prompts)
    lens = np.array([len(p) for p in prompts])
    pmax = int(lens.max())
    toks = np.zeros((bsz, pmax), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    kv = jnp.zeros((bsz, cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim), jnp.float32)
    logits, kv = fwd(params, jnp.asarray(toks), kv, jnp.zeros(bsz, jnp.int32))
    logits = np.asarray(logits)[np.arange(bsz), lens - 1]  # next-token logits

    seqs = [list(p) for p in prompts]
    done = np.zeros(bsz, bool)
    pos = lens.copy()
    for _ in range(max_new):
        if temperature <= 0.0:
            nxt = np.argmax(logits, axis=-1)
        else:
            z = logits / temperature
            z -= z.max(axis=-1, keepdims=True)
            probs = np.exp(z)
            probs /= probs.sum(axis=-1, keepdims=True)
            nxt = _top_p_sample(rng, probs, top_p)
        for b in range(bsz):
            if not done[b]:
                seqs[b].append(int(nxt[b]))
                if nxt[b] == EOS or pos[b] + 1 >= cfg.max_seq - 1:
                    done[b] = True
        if done.all():
            break
        logits, kv = fwd(
            params,
            jnp.asarray(nxt[:, None].astype(np.int32)),
            kv,
            jnp.asarray(pos.astype(np.int32)),
        )
        logits = np.asarray(logits)[:, 0]
        pos += 1
    return seqs


def build_distill_dataset(
    target_params,
    synth: SynthChat,
    tc: TrainConfig,
    tasks: Sequence[str],
    seed: int,
) -> List[Tuple[List[int], int]]:
    """Phase 2. Returns [(tokens, prompt_len)]: target-generated responses to
    seed instructions across the temperature grid. prompt_len marks where the
    distillation loss mask starts (we distill on response tokens only)."""
    seeds = synth.seed_prompts(seed, tc.distill_prompts, tasks)
    out: List[Tuple[List[int], int]] = []
    chunk = 32
    for ti, temp in enumerate(tc.distill_temperatures):
        for lo in range(0, len(seeds), chunk):
            batch = seeds[lo : lo + chunk]
            gen = generate_batch(
                target_params,
                TARGET_CONFIG,
                [ex.prompt for ex in batch],
                tc.distill_max_new,
                temp,
                tc.distill_top_p,
                seed=seed * 1000 + ti * 100 + lo,
            )
            out.extend((g, len(ex.prompt)) for g, ex in zip(gen, batch))
        print(f"[distill-gen] temp={temp} -> {len(out)} sequences", flush=True)
    return out


# ---------------------------------------------------------------------------
# Phase 3: draft finetuning via white-box KD (teacher in the loop)
# ---------------------------------------------------------------------------


# Fill value for vocabulary entries outside a captured top-k row: softmax
# sends exp(-1e9 - max) to exactly 0, so the sparse teacher is the
# renormalized top-k distribution.
CAPTURE_LOGIT_FLOOR = -1e9


def make_finetune_step(loss_name: str, tc: TrainConfig, total_steps: int,
                       captured_teacher: bool = False):
    """Finetune step factory. With `captured_teacher` the teacher
    distribution comes from the `q_teacher` argument (target top-k logits
    captured by `specd distill`, scattered onto the full vocab grid)
    instead of a live target forward pass — the paper's phase-3 setup
    against the *recorded* target distribution, and one whole target
    forward cheaper per step."""
    warmup = max(1, int(tc.warmup_frac * total_steps))

    @jax.jit
    def step(draft_params, target_params, opt_state, tokens, dist_w, lm_w, q_teacher):
        """tokens: [B, T+1]; dist_w masks distill-response positions (on the
        *label* grid), lm_w masks pretraining-row positions. q_teacher:
        [B, T, V] captured teacher logits (any placeholder when
        `captured_teacher` is off — the live branch never reads it)."""
        inputs, labels = tokens[:, :-1], tokens[:, 1:]

        def loss_fn(p):
            p_logits = model.forward_train(p, DRAFT_CONFIG, inputs)
            if captured_teacher:
                q_logits = q_teacher
            else:
                q_logits = model.forward_train(target_params, TARGET_CONFIG, inputs)
            l_dist = losses.distill_loss(loss_name, p_logits, q_logits, dist_w)
            l_lm = losses.next_token_loss(p_logits, labels, lm_w)
            return l_dist + l_lm, (l_dist, l_lm)

        (loss, (l_dist, l_lm)), grads = jax.value_and_grad(loss_fn, has_aux=True)(draft_params)
        lr = optim.warmup_decay_lr(opt_state["step"], total_steps, tc.lr_max, tc.lr_min, warmup)
        draft_params, opt_state = optim.adamw_update(
            draft_params, grads, opt_state, lr=lr,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip,
        )
        return draft_params, opt_state, loss, l_dist, l_lm

    return step


def finetune_draft(
    draft_params,
    target_params,
    distill_set: List[Tuple[List[int], int]],
    synth: SynthChat,
    tc: TrainConfig,
    loss_name: str,
    ckpt_hook,
    capture: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None,
):
    """Phase 3 for one loss. `ckpt_hook(ckpt_index, params)` is called at the
    n_checkpoints evenly spaced points (paper Figure 2's x-axis).

    `capture`, when given, is parallel to `distill_set`: per record the
    (topk_ids [R, k], topk_logits [R, k]) arrays from a `specd distill`
    shard dataset. The distillation loss then runs against the captured
    target distribution (scattered onto the vocab grid) instead of a live
    target forward pass."""
    if capture is not None and len(capture) != len(distill_set):
        raise ValueError("capture must be parallel to distill_set")
    # Stable per-loss seed: builtin hash() is salted per process
    # (PYTHONHASHSEED), which would make finetuning unreproducible.
    loss_seed = int.from_bytes(hashlib.sha256(loss_name.encode()).digest()[:4], "little")
    rng = np.random.default_rng(loss_seed)
    step_fn = make_finetune_step(loss_name, tc, tc.finetune_steps,
                                 captured_teacher=capture is not None)
    opt_state = optim.adamw_init(draft_params)
    pre_batches = batch_stream(synth.corpus_stream(seed=999), tc.seq_len, tc.batch_size)
    n_dist_rows = max(1, int(round(tc.distill_mix_ratio * tc.batch_size)))
    t_len = tc.seq_len
    vocab = TARGET_CONFIG.vocab_size

    def sample_rows():
        tokens = np.zeros((tc.batch_size, t_len + 1), np.int32)
        dist_w = np.zeros((tc.batch_size, t_len), np.float32)
        lm_w = np.zeros((tc.batch_size, t_len), np.float32)
        if capture is not None:
            q = np.full((tc.batch_size, t_len, vocab), CAPTURE_LOGIT_FLOOR, np.float32)
        else:
            q = np.zeros((1,), np.float32)  # placeholder; live branch ignores it
        # distillation rows (loss vs teacher on response positions)
        for b in range(n_dist_rows):
            i = int(rng.integers(len(distill_set)))
            seq, plen = distill_set[i]
            seq = seq[: t_len + 1]
            tokens[b, : len(seq)] = seq
            # label index j predicts token j+1: response tokens start at plen
            dist_w[b, max(plen - 1, 0) : max(len(seq) - 1, 0)] = 1.0
            if capture is not None:
                ids, logits = capture[i]
                # Captured row j is the target's distribution for response
                # token j = seq[plen + j], i.e. label position plen - 1 + j.
                # Vectorized scatter: one fancy-index write per row, no
                # per-position Python loop. Rows whose label position falls
                # below 0 (a pathological plen = 0 record) are dropped, the
                # same guard dist_w applies above — never negative-index q.
                n = len(seq) - plen
                skip = max(plen - 1, 0) - (plen - 1)
                if n > skip:
                    pos = np.arange(plen - 1 + skip, plen - 1 + n)
                    q[b, pos[:, None], ids[skip:n]] = logits[skip:n]
        # pretraining rows (regularization, plain next-token loss)
        pre = next(pre_batches)
        for b in range(n_dist_rows, tc.batch_size):
            tokens[b] = pre[b - n_dist_rows]
            lm_w[b, :] = 1.0
        return jnp.asarray(tokens), jnp.asarray(dist_w), jnp.asarray(lm_w), jnp.asarray(q)

    ckpt_every = max(1, tc.finetune_steps // tc.n_checkpoints)
    t0 = time.time()
    for i in range(tc.finetune_steps):
        tokens, dist_w, lm_w, q_teacher = sample_rows()
        draft_params, opt_state, loss, l_dist, l_lm = step_fn(
            draft_params, target_params, opt_state, tokens, dist_w, lm_w, q_teacher
        )
        if i % 50 == 0 or i == tc.finetune_steps - 1:
            print(f"[finetune:{loss_name}] step {i:4d}/{tc.finetune_steps} "
                  f"loss={float(loss):.4f} dist={float(l_dist):.4f} "
                  f"lm={float(l_lm):.4f} ({time.time()-t0:.0f}s)", flush=True)
        if (i + 1) % ckpt_every == 0:
            ckpt_hook((i + 1) // ckpt_every, draft_params)
    return draft_params


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------


def save_params(path: str, params) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> Dict[str, jnp.ndarray]:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def smoke_config() -> TrainConfig:
    """Tiny profile for CI / pytest smoke runs."""
    return TrainConfig(
        batch_size=4, seq_len=48,
        pretrain_steps_draft=8, pretrain_steps_target=8, target_sft_steps=8,
        distill_prompts=8, distill_max_new=8, finetune_steps=8, n_checkpoints=2,
    )


def run_pipeline(
    out_dir: str,
    tc: TrainConfig,
    include_wmt: bool = False,
    seed: int = 0,
    distill_dir: str | None = None,
):
    """Full pipeline. When `distill_dir` points at a `specd distill` shard
    directory (Rust-side bulk generation), phase 2 loads those shards
    instead of regenerating responses here — the serving stack is much
    faster at saturating the target model than this reference loop."""
    os.makedirs(out_dir, exist_ok=True)
    synth = SynthChat()
    meta = {"include_wmt": include_wmt, "seed": seed, "losses": list(losses.LOSS_NAMES)}

    # --- Phase 1: pretraining --------------------------------------------
    target_params = model.init_params(TARGET_CONFIG, seed + 1)
    draft_params = model.init_params(DRAFT_CONFIG, seed + 2)
    target_params, l_t = train_next_token(
        target_params, TARGET_CONFIG, tc,
        synth.corpus_stream(seed=101), tc.pretrain_steps_target, "pretrain:target")
    draft_params, l_d = train_next_token(
        draft_params, DRAFT_CONFIG, tc,
        synth.corpus_stream(seed=202), tc.pretrain_steps_draft, "pretrain:draft")
    # Chat-SFT the target on ALL tasks (incl. wmt) => the chat-capable target.
    target_params, l_sft = train_next_token(
        target_params, TARGET_CONFIG, tc,
        synth.sft_stream(seed=303), tc.target_sft_steps, "sft:target")
    save_params(os.path.join(out_dir, "target.npz"), target_params)
    save_params(os.path.join(out_dir, "draft_base.npz"), draft_params)
    meta["pretrain_loss"] = {"target": l_t, "draft": l_d, "target_sft": l_sft}

    # --- Phase 2: distillation dataset from the target --------------------
    capture = None
    if distill_dir is not None:
        records = data_mod.load_distill_shards(distill_dir)
        if not records:
            # Fail in seconds, not hours into phase 1: an interrupted
            # `specd distill` run can leave a valid manifest with 0 shards.
            raise ValueError(f"{distill_dir}: dataset has no committed records")
        distill_set = data_mod.distill_set_from_records(records)
        if records and records[0].topk_ids is not None:
            capture = [(r.topk_ids, r.topk_logits) for r in records]
            meta["distill_capture_topk"] = int(records[0].topk_ids.shape[1])
        else:
            meta["distill_capture_topk"] = 0
        meta["distill_source"] = distill_dir
        tasks = tuple(sorted({r.task for r in records}))
        if "wmt" in tasks:
            raise ValueError("shard dataset contains wmt seeds (OOD protocol violation)")
    else:
        tasks = ("dolly", "xsum", "cnndm") + (("wmt",) if include_wmt else ())
        distill_set = build_distill_dataset(target_params, synth, tc, tasks, seed=404)
    meta["distill_sequences"] = len(distill_set)
    meta["distill_tasks"] = list(tasks)

    # --- Phase 3: finetune one draft per loss ------------------------------
    for loss_name in losses.LOSS_NAMES:
        def hook(ck, p, loss_name=loss_name):
            save_params(os.path.join(out_dir, f"draft_{loss_name}_ckpt{ck}.npz"), p)
        print(f"=== finetune loss={loss_name} ===", flush=True)
        finetune_draft(dict(draft_params), target_params, distill_set, synth, tc,
                       loss_name, hook, capture=capture)

    meta["n_checkpoints"] = tc.n_checkpoints
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"pipeline complete -> {out_dir}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/train")
    ap.add_argument("--profile", choices=("full", "smoke"), default="full")
    ap.add_argument("--include-wmt", action="store_true",
                    help="ablation: add wmt to the distillation seeds (§A.5)")
    ap.add_argument("--distill-data", default=None,
                    help="`specd distill` shard directory; skips phase-2 generation")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    tc = TRAIN_CONFIG if args.profile == "full" else smoke_config()
    run_pipeline(args.out, tc, include_wmt=args.include_wmt, seed=args.seed,
                 distill_dir=args.distill_data)


if __name__ == "__main__":
    main()

"""Rule configuration for specd-lint.

Everything repo-specific lives here so the rules themselves stay generic
and fixture-testable.  The defaults encode this repo's invariants:

  * hot-path modules: the scheduler/engine files where a panic takes the
    whole serving loop (and every in-flight request) down with it.
  * chokepoints: PR 6's one-terminal-per-request invariant -- the listed
    tokens may only appear inside the named function.
  * metrics: `specd_*` family names defined in metrics.rs must match the
    documented tables (docs/METRICS.md + README.md) exactly, and every
    reference elsewhere in the tree must resolve to a defined family.
  * lock order: configured mutex pairs; within one function the first
    name must be locked before the second is ever locked.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass
class Config:
    # ---- no-panic ---------------------------------------------------------
    # Modules where unwrap/expect/panic in non-test code is forbidden.
    hot_path_modules: List[str] = field(
        default_factory=lambda: [
            "runtime.rs",
            "batch.rs",
            "spec.rs",
            "coordinator.rs",
            "datagen.rs",
            "trace.rs",
            "telemetry.rs",
            "faults.rs",
            "lifecycle.rs",
        ]
    )
    panic_patterns: List[Tuple[str, str]] = field(
        default_factory=lambda: [
            (r"\.unwrap\(\)", ".unwrap()"),
            (r"\.expect\s*\(", ".expect(…)"),
            (r"(?:^|[^\w:])panic!\s*[\(\{]", "panic!"),
            (r"(?:^|[^\w:])unreachable!\s*[\(\{]", "unreachable!"),
            (r"(?:^|[^\w:])todo!\s*[\(\{]", "todo!"),
            (r"(?:^|[^\w:])unimplemented!\s*[\(\{]", "unimplemented!"),
        ]
    )

    # ---- hot-path-alloc ---------------------------------------------------
    # Allocation idioms banned inside `// lint: hot-path` regions (the
    # PR 4 host-allocation purge: staging buffers are reused, never grown
    # per dispatch).
    alloc_patterns: List[Tuple[str, str]] = field(
        default_factory=lambda: [
            (r"Vec::new\s*\(", "Vec::new()"),
            (r"Vec::with_capacity\s*\(", "Vec::with_capacity()"),
            (r"(?:^|[^\w:])vec!\s*\[", "vec![]"),
            (r"\.to_vec\(\)", ".to_vec()"),
            (r"(?:^|[^\w:])format!\s*\(", "format!()"),
            (r"String::from\s*\(", "String::from()"),
            (r"String::new\s*\(", "String::new()"),
            (r"\.to_string\(\)", ".to_string()"),
            (r"\.clone\(\)", ".clone()"),
            (r"Box::new\s*\(", "Box::new()"),
            (r"\.collect\s*(?:::<[^>]*>\s*)?\(", ".collect()"),
        ]
    )

    # ---- one-terminal (structural chokepoints) ----------------------------
    # file -> (functions, tokens): each token may appear in non-test code
    # of that file only inside one of the named functions (a bare string
    # names exactly one; an empty list bans the tokens outright).  Enforces
    # that every coordinator exit path flows through `terminal()` -- or,
    # for requests orphaned by a scheduler death, the supervisor's
    # `strand_terminal()` -- and that the lifecycle supervisor itself never
    # sends a terminal behind the coordinator's back.
    chokepoints: Dict[str, Tuple[object, List[str]]] = field(
        default_factory=lambda: {
            "coordinator.rs": (
                ["terminal", "strand_terminal"],
                [r"\btx\s*\.\s*send\s*\(", r"Delta::Done"],
            ),
            "lifecycle.rs": ([], [r"\btx\s*\.\s*send\s*\(", r"Delta::Done"]),
        }
    )

    # ---- metrics-doc ------------------------------------------------------
    # Files whose non-test string literals *define* metric families
    # (metrics.rs renders the engine families, server.rs the HTTP-layer
    # counters, telemetry.rs the specd_health_* speculation-health
    # family).  Everything else only *references* them.
    metrics_def_files: List[str] = field(
        default_factory=lambda: [
            "metrics.rs",
            "server.rs",
            "telemetry.rs",
            "faults.rs",
            "lifecycle.rs",
        ]
    )
    metrics_doc_files: List[str] = field(
        default_factory=lambda: ["docs/METRICS.md", "README.md"]
    )
    metrics_prefix: str = "specd_"
    # Reference tokens that are not metric families (temp file names, the
    # linter's own name inside `test_specd_lint.py` mentions).
    metrics_ignore: List[str] = field(
        default_factory=lambda: ["specd_bench_json_test", "specd_lint"]
    )

    # ---- fault-site -------------------------------------------------------
    # Every call of this pattern in non-test code is a deterministic fault
    # injection point and must carry a `// lint: fault-site(<id>)` marker
    # (same line or the line above); ids are unique repo-wide and stale
    # markers (no call underneath) are violations.  The marker inventory is
    # the operator-facing catalogue of what `--fault-plan` can hit.
    fault_inject_pattern: str = r"(?:crate::|specd::)?faults::inject\s*\("

    # ---- trace-pairing ----------------------------------------------------
    trace_begin: str = r"(?:crate::|specd::)?trace::begin\s*\(\s*\)"
    trace_closers: List[str] = field(
        default_factory=lambda: ["phase", "iteration", "wave", "dispatch"]
    )

    # ---- lock-order -------------------------------------------------------
    # (first, second): when both appear in one function, `first.lock()`
    # must come before `second.lock()`.  The pairs fix a global order for
    # the three long-lived mutexes (channel queue -> trace recorder ->
    # metrics aggregate) so new code cannot introduce an inversion.
    lock_order: List[Tuple[str, str]] = field(
        default_factory=lambda: [
            ("queue", "RECORDER"),
            ("RECORDER", "agg"),
            ("queue", "agg"),
        ]
    )


def default_config() -> Config:
    return Config()

"""specd-lint: a toolchain-independent invariant analyzer for rust/src.

The serving stack's correctness rests on hand-maintained invariants
(one-terminal-per-request, the hot-path allocation purge, trace span
pairing) that `cargo` cannot check -- and most growth containers have no
Rust toolchain at all.  This package is a stdlib-only analyzer that
parses the Rust sources directly, so the invariants gate every container.

Entry points:
  scripts/lint_specd.py        repo-facing CLI wrapper
  python -m tools.specd_lint   equivalent module invocation
"""

from .model import RustFile, Directive
from .rules import ALL_RULES, Violation, run_rules
from .config import Config, default_config

__all__ = [
    "RustFile",
    "Directive",
    "ALL_RULES",
    "Violation",
    "run_rules",
    "Config",
    "default_config",
]

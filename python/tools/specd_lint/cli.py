"""Command-line driver: walk rust/src, run every rule, report, exit 1.

Usage (from anywhere inside the repo):

    python3 scripts/lint_specd.py            # lint the repo
    python3 scripts/lint_specd.py --rules no-panic,one-terminal
    python3 scripts/lint_specd.py --dump-metrics   # exported families

Needs nothing beyond the Python standard library -- this is the Rust
gate for containers without a Rust toolchain.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List

from .config import default_config
from .model import parse_rust
from .rules import ALL_RULES, Repo, run_rules


def find_repo_root(start: str) -> str:
    d = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(d, "Cargo.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise SystemExit("specd-lint: no Cargo.toml above " + start)
        d = parent


def load_repo(root: str) -> Repo:
    cfg = default_config()
    files = []
    src = os.path.join(root, "rust", "src")
    for dirpath, _, names in os.walk(src):
        for name in sorted(names):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            files.append(parse_rust(os.path.relpath(path, root), text))
    docs = {}
    for rel in cfg.metrics_doc_files:
        path = os.path.join(root, rel)
        if os.path.exists(path):
            with open(path, encoding="utf-8") as fh:
                docs[rel] = fh.read()
    return Repo(files=files, docs=docs, cfg=cfg)


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(prog="specd-lint", description=__doc__)
    ap.add_argument("--root", default=None, help="repo root (default: auto-detect)")
    ap.add_argument(
        "--rules",
        default=None,
        help="comma-separated subset of rules to run (default: all)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="list rule names and exit"
    )
    ap.add_argument(
        "--dump-metrics",
        action="store_true",
        help="print the exported specd_* metric families and exit "
        "(source for the docs/METRICS.md table)",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in ALL_RULES:
            print(name)
        return 0

    root = args.root or find_repo_root(os.getcwd())
    repo = load_repo(root)

    if args.dump_metrics:
        from .rules import _defined_families

        for fam in sorted(_defined_families(repo)):
            print(fam)
        return 0

    only = args.rules.split(",") if args.rules else None
    if only:
        unknown = [r for r in only if r not in ALL_RULES]
        if unknown:
            print(f"specd-lint: unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    violations = run_rules(repo, only=only)
    for v in violations:
        print(v.render())
    n_files = len(repo.files)
    n_rules = len(only) if only else len(ALL_RULES)
    if violations:
        print(
            f"specd-lint: {len(violations)} violation(s) across {n_files} files "
            f"({n_rules} rules)",
            file=sys.stderr,
        )
        return 1
    print(f"specd-lint: OK ({n_files} files, {n_rules} rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""The seven rule families specd-lint enforces over ``rust/src``.

Every rule is a pure function ``(repo: Repo) -> List[Violation]`` so the
test suite can feed it single-file fixtures.  Escapes: a
``// lint: allow(<rule>, <reason>)`` comment on the offending line or the
line directly above suppresses that one finding; the reason is mandatory
(empty reasons are themselves a violation).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .config import Config
from .model import RustFile


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class Repo:
    """Everything the rules look at: parsed sources + raw doc files."""

    files: List[RustFile]
    docs: Dict[str, str] = field(default_factory=dict)  # path -> text
    cfg: Config = field(default_factory=Config)

    def file(self, name: str):
        for f in self.files:
            if f.name == name:
                return f
        return None


def _check_allow(rf: RustFile, rule: str, line: int, out: List[Violation]) -> bool:
    """True when an allow() escape covers (rule, line); flags empty reasons."""
    for d in rf.directives:
        if d.kind == "allow" and d.rule == rule and d.line in (line, line - 1):
            if not d.reason:
                out.append(
                    Violation(
                        rule,
                        rf.path,
                        d.line,
                        "allow() escape needs a non-empty reason",
                    )
                )
            return True
    return False


# ---------------------------------------------------------------------------
# Rule 1: no-panic -- unwrap/expect/panic in hot-path modules
# ---------------------------------------------------------------------------


def rule_no_panic(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    pats = [(re.compile(p), label) for p, label in repo.cfg.panic_patterns]
    for rf in repo.files:
        if rf.name not in repo.cfg.hot_path_modules:
            continue
        for lineno, text in rf.code_lines():
            for pat, label in pats:
                if not pat.search(text):
                    continue
                if _check_allow(rf, "no-panic", lineno, out):
                    continue
                out.append(
                    Violation(
                        "no-panic",
                        rf.path,
                        lineno,
                        f"{label} in hot-path module {rf.name}: a panic here "
                        "kills the scheduler and every in-flight request; "
                        "return a crate::error::Error instead",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# Rule 2: hot-path-alloc -- allocation idioms inside `// lint: hot-path`
# ---------------------------------------------------------------------------


def rule_hot_path_alloc(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    pats = [(re.compile(p), label) for p, label in repo.cfg.alloc_patterns]
    for rf in repo.files:
        if rf.unterminated_hot is not None:
            out.append(
                Violation(
                    "hot-path-alloc",
                    rf.path,
                    rf.unterminated_hot,
                    "`// lint: hot-path` region is never closed "
                    "(missing `// lint: end-hot-path`)",
                )
            )
        if not rf.hot_ranges:
            continue
        for lineno, text in rf.code_lines():
            if not rf.in_hot_range(lineno):
                continue
            for pat, label in pats:
                if not pat.search(text):
                    continue
                if _check_allow(rf, "hot-path-alloc", lineno, out):
                    continue
                out.append(
                    Violation(
                        "hot-path-alloc",
                        rf.path,
                        lineno,
                        f"{label} inside a hot-path region: the PR-4 purge "
                        "keeps per-dispatch staging allocation-free -- reuse "
                        "a scratch buffer",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# Rule 3: one-terminal -- structural chokepoints
# ---------------------------------------------------------------------------


def rule_one_terminal(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    for fname, (func, tokens) in repo.cfg.chokepoints.items():
        rf = repo.file(fname)
        if rf is None:
            continue
        # A chokepoint may name one function or a list of them (e.g. the
        # coordinator's normal `terminal` plus the supervisor's
        # `strand_terminal` for requests orphaned by a scheduler death).
        # An empty list means the tokens may not appear in the file at all.
        funcs = [func] if isinstance(func, str) else list(func)
        pats = [re.compile(t) for t in tokens]
        for lineno, text in rf.code_lines():
            for pat in pats:
                if not pat.search(text):
                    continue
                enclosing = rf.enclosing_function(lineno)
                if enclosing in funcs:
                    continue
                if _check_allow(rf, "one-terminal", lineno, out):
                    continue
                allowed = ", ".join(f"{f}()" for f in funcs) or "<no function>"
                out.append(
                    Violation(
                        "one-terminal",
                        rf.path,
                        lineno,
                        f"`{pat.pattern}` outside {allowed} "
                        f"(in {enclosing or 'module scope'}): every request "
                        f"exit must route through a terminal chokepoint exactly once",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# Rule 4: metrics-doc -- specd_* families vs the documented tables
# ---------------------------------------------------------------------------

_FAMILY_RE = re.compile(r"^specd_[a-z0-9_]+$")


def _defined_families(repo: Repo) -> Dict[str, Tuple[str, int]]:
    """Family -> (file, first definition line), from string literals in the
    configured definition files' non-test code (`prom_counter("specd_…")`
    and histogram renders)."""
    fams: Dict[str, Tuple[str, int]] = {}
    for name in repo.cfg.metrics_def_files:
        rf = repo.file(name)
        if rf is None:
            continue
        for i, strings in enumerate(rf.strings):
            if rf.is_test[i]:
                continue
            for s in strings:
                if _FAMILY_RE.match(s):
                    fams.setdefault(s, (name, i + 1))
    return fams


def _doc_tokens(text: str) -> List[str]:
    return re.findall(r"specd_[a-z0-9_]+\*?", text)


def rule_metrics_doc(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    cfg = repo.cfg
    defined = _defined_families(repo)
    if not defined and all(repo.file(n) is None for n in cfg.metrics_def_files):
        return out  # fixture runs without any definition file

    doc_tokens: List[Tuple[str, str]] = []  # (token, doc path)
    for path, text in repo.docs.items():
        for tok in _doc_tokens(text):
            doc_tokens.append((tok, path))
    doc_exact = {t for t, _ in doc_tokens if not t.endswith(("*", "_"))}
    doc_prefix = {t.rstrip("*_") for t, _ in doc_tokens if t.endswith(("*", "_"))}

    # (a) every defined family is documented (exactly or via a glob row)
    for fam, (def_name, line) in sorted(defined.items()):
        if fam in doc_exact or any(fam.startswith(p) for p in doc_prefix):
            continue
        def_file = repo.file(def_name)
        if def_file is not None and _check_allow(def_file, "metrics-doc", line, out):
            continue
        out.append(
            Violation(
                "metrics-doc",
                def_file.path if def_file else def_name,
                line,
                f"metric family `{fam}` is exported but missing from the "
                f"documented tables ({', '.join(cfg.metrics_doc_files)})",
            )
        )

    # (b) every documented token resolves to a defined family
    for tok, path in sorted(set(doc_tokens)):
        if tok in cfg.metrics_ignore or tok.rstrip("*_") in cfg.metrics_ignore:
            continue
        if tok.endswith(("*", "_")):
            prefix = tok.rstrip("*_")
            if any(f.startswith(prefix) for f in defined):
                continue
            out.append(
                Violation(
                    "metrics-doc",
                    path,
                    0,
                    f"documented glob `{tok}` matches no exported family",
                )
            )
        elif tok not in defined:
            out.append(
                Violation(
                    "metrics-doc",
                    path,
                    0,
                    f"documented family `{tok}` is not exported by "
                    f"{' / '.join(cfg.metrics_def_files)}",
                )
            )

    # (c) every reference in the sources resolves to a defined family
    #     (comments included: stale names in doc comments mislead operators)
    ref_re = re.compile(r"specd_[a-z0-9_]+\*?")
    for rf in repo.files:
        if rf.name in cfg.metrics_def_files:
            continue
        for i, line in enumerate(rf.raw):
            if rf.is_test[i]:
                continue
            for tok in ref_re.findall(line):
                base = tok.rstrip("*_")
                if tok in cfg.metrics_ignore or base in cfg.metrics_ignore:
                    continue
                ok = (
                    tok in defined
                    if not tok.endswith(("*", "_"))
                    else any(f.startswith(base) for f in defined)
                )
                if ok:
                    continue
                if _check_allow(rf, "metrics-doc", i + 1, out):
                    continue
                out.append(
                    Violation(
                        "metrics-doc",
                        rf.path,
                        i + 1,
                        f"reference to `{tok}` matches no exported metric "
                        "family (stale name?)",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# Rule 5: fault-site -- every faults::inject() call is marked and unique
# ---------------------------------------------------------------------------


def rule_fault_site(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    inject = re.compile(repo.cfg.fault_inject_pattern)
    seen_ids: Dict[str, Tuple[str, int]] = {}  # id -> (path, line)
    for rf in repo.files:
        if rf.name == "faults.rs":
            continue  # the machinery itself, not an injection point
        markers = {d.line: d for d in rf.directives if d.kind == "fault-site"}
        call_lines = set()
        for lineno, text in rf.code_lines():
            if not inject.search(text):
                continue
            call_lines.add(lineno)
            d = markers.get(lineno) or markers.get(lineno - 1)
            if d is None:
                if _check_allow(rf, "fault-site", lineno, out):
                    continue
                out.append(
                    Violation(
                        "fault-site",
                        rf.path,
                        lineno,
                        "faults::inject() call without a "
                        "`// lint: fault-site(<id>)` marker: every injection "
                        "point must be named so --fault-plan coverage is "
                        "auditable",
                    )
                )
                continue
            prev = seen_ids.get(d.rule)
            if prev is not None:
                out.append(
                    Violation(
                        "fault-site",
                        rf.path,
                        d.line,
                        f"fault-site id `{d.rule}` already used at "
                        f"{prev[0]}:{prev[1]}: ids are unique repo-wide",
                    )
                )
            else:
                seen_ids[d.rule] = (rf.path, d.line)
        # stale markers: a named site whose injection call went away would
        # silently shrink --fault-plan coverage
        for d in sorted(markers.values(), key=lambda d: d.line):
            if d.line in call_lines or (d.line + 1) in call_lines:
                continue
            if _check_allow(rf, "fault-site", d.line, out):
                continue
            out.append(
                Violation(
                    "fault-site",
                    rf.path,
                    d.line,
                    f"stale `// lint: fault-site({d.rule})` marker: no "
                    "faults::inject() call on this line or the next",
                )
            )
    return out


# ---------------------------------------------------------------------------
# Rule 6: trace-pairing -- every trace::begin() feeds a span closer
# ---------------------------------------------------------------------------


def rule_trace_pairing(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    cfg = repo.cfg
    begin_let = re.compile(r"let\s+(?:mut\s+)?(\w+)\s*=\s*" + cfg.trace_begin)
    begin_any = re.compile(cfg.trace_begin)
    closers = "|".join(cfg.trace_closers)
    for rf in repo.files:
        for name, a, b in rf.functions:
            lines = [
                (i + 1, rf.code[i])
                for i in range(a - 1, b)
                if not rf.is_test[i]
            ]
            if not lines:
                continue
            body = "\n".join(t for _, t in lines)
            for lineno, text in lines:
                for m in begin_any.finditer(text):
                    lm = begin_let.search(text)
                    if lm is None or lm.end() < m.end():
                        # begin() not bound to a variable at this site
                        if _check_allow(rf, "trace-pairing", lineno, out):
                            continue
                        out.append(
                            Violation(
                                "trace-pairing",
                                rf.path,
                                lineno,
                                "trace::begin() result discarded: bind it and "
                                "close the span with "
                                f"trace::{{{closers}}}(t0, …)",
                            )
                        )
                        continue
                    var = lm.group(1)
                    closer = re.compile(
                        r"trace::(?:" + closers + r")\s*\(\s*" + re.escape(var) + r"\b",
                        re.S,
                    )
                    if closer.search(body):
                        continue
                    if _check_allow(rf, "trace-pairing", lineno, out):
                        continue
                    out.append(
                        Violation(
                            "trace-pairing",
                            rf.path,
                            lineno,
                            f"span opened as `{var}` in fn {name}() is never "
                            f"closed by trace::{{{closers}}}({var}, …) -- the "
                            "ring would record an unterminated span",
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# Rule 7: lock-order -- configured mutex acquisition order
# ---------------------------------------------------------------------------


def rule_lock_order(repo: Repo) -> List[Violation]:
    out: List[Violation] = []
    for rf in repo.files:
        for name, a, b in rf.functions:
            first_at: Dict[str, int] = {}
            for lock_name in {n for pair in repo.cfg.lock_order for n in pair}:
                pat = re.compile(r"(?:^|[^\w])" + re.escape(lock_name) + r"\s*\.\s*lock\s*\(")
                for i in range(a - 1, b):
                    if rf.is_test[i]:
                        continue
                    if pat.search(rf.code[i]):
                        first_at[lock_name] = i + 1
                        break
            for first, second in repo.cfg.lock_order:
                if first in first_at and second in first_at:
                    if first_at[second] < first_at[first]:
                        lineno = first_at[second]
                        if _check_allow(rf, "lock-order", lineno, out):
                            continue
                        out.append(
                            Violation(
                                "lock-order",
                                rf.path,
                                lineno,
                                f"`{second}.lock()` acquired before "
                                f"`{first}.lock()` in fn {name}(): the "
                                f"configured order is {first} -> {second} "
                                "(deadlock risk on the inverse nesting)",
                            )
                        )
    return out


ALL_RULES = {
    "no-panic": rule_no_panic,
    "hot-path-alloc": rule_hot_path_alloc,
    "one-terminal": rule_one_terminal,
    "metrics-doc": rule_metrics_doc,
    "fault-site": rule_fault_site,
    "trace-pairing": rule_trace_pairing,
    "lock-order": rule_lock_order,
}


def run_rules(repo: Repo, only: List[str] = None) -> List[Violation]:
    out: List[Violation] = []
    for name, rule in ALL_RULES.items():
        if only and name not in only:
            continue
        out.extend(rule(repo))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out

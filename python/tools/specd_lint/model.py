"""Lexical model of one Rust source file, stdlib-only.

Not a parser: a line-oriented scanner that is exact about the three
things the rules need and deliberately naive about everything else.

  * ``code[i]``     -- line i with comments and string/char literal
                       *contents* blanked (structure preserved), so regex
                       rules never fire inside strings or comments.
  * ``strings[i]``  -- the string-literal contents that were blanked
                       (the metrics rule reads family names from these).
  * ``is_test[i]``  -- inside a ``#[cfg(test)]`` module / ``#[test]`` fn.
  * ``functions``   -- (name, first_line, last_line) spans via brace
                       matching on the blanked code.
  * ``directives``  -- parsed ``// lint: ...`` markers (see grammar in
                       README §Static analysis & invariants).

Handles ``//`` and nesting ``/* */`` comments, ordinary strings with
escapes, raw strings ``r"…"`` / ``r#"…"#``, and char literals without
tripping over lifetimes (``'a``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

_LINT_RE = re.compile(
    r"//\s*lint:\s*(allow\(\s*([a-z0-9-]+)\s*,\s*([^)]*)\)|hot-path|end-hot-path"
    r"|fault-site\(\s*([a-z0-9_:-]+)\s*\))"
)
_FN_RE = re.compile(r"(?:^|[^\w])fn\s+(\w+)\s*[(<]")
_CFG_TEST_RE = re.compile(r"#\s*\[\s*cfg\s*\(\s*test\s*\)\s*\]")
_TEST_ATTR_RE = re.compile(r"#\s*\[\s*test\s*\]")


@dataclass
class Directive:
    """One ``// lint:`` marker."""

    kind: str  # "allow" | "hot-path" | "end-hot-path" | "fault-site"
    line: int  # 1-based
    rule: str = ""  # for allow; the site id for fault-site
    reason: str = ""  # for allow


@dataclass
class RustFile:
    path: str  # path as given (used in diagnostics)
    name: str  # basename, e.g. "spec.rs"
    raw: List[str] = field(default_factory=list)
    code: List[str] = field(default_factory=list)
    strings: List[List[str]] = field(default_factory=list)
    is_test: List[bool] = field(default_factory=list)
    functions: List[Tuple[str, int, int]] = field(default_factory=list)
    directives: List[Directive] = field(default_factory=list)
    hot_ranges: List[Tuple[int, int]] = field(default_factory=list)
    unterminated_hot: Optional[int] = None  # line of a hot-path with no end

    # -- queries -----------------------------------------------------------

    def allowed(self, rule: str, line: int) -> bool:
        """An ``allow(rule, …)`` on this line or the line above escapes it."""
        for d in self.directives:
            if d.kind == "allow" and d.rule == rule and d.line in (line, line - 1):
                return True
        return False

    def in_hot_range(self, line: int) -> bool:
        return any(a < line < b for a, b in self.hot_ranges)

    def enclosing_function(self, line: int) -> Optional[str]:
        best = None
        for name, a, b in self.functions:
            if a <= line <= b:
                # innermost (latest-starting) span wins for nested fns
                if best is None or a >= best[1]:
                    best = (name, a)
        return best[0] if best else None

    def code_lines(self, include_tests: bool = False):
        """Yield (1-based line number, blanked code) for rule scans."""
        for i, text in enumerate(self.code):
            if not include_tests and self.is_test[i]:
                continue
            yield i + 1, text


def parse_rust(path: str, text: str) -> RustFile:
    rf = RustFile(path=path, name=path.rsplit("/", 1)[-1])
    rf.raw = text.splitlines()
    _scan(rf)
    _mark_tests(rf)
    _find_functions(rf)
    _collect_directives(rf)
    return rf


# ---------------------------------------------------------------------------
# pass 1: blank comments and literals, collect // lint: directives
# ---------------------------------------------------------------------------


def _scan(rf: RustFile) -> None:
    in_block = 0  # /* */ nesting depth
    raw_hashes: Optional[int] = None  # inside r#"…"# with this many #
    for lineno, line in enumerate(rf.raw):
        out: List[str] = []
        strings: List[str] = []
        i, n = 0, len(line)
        while i < n:
            c = line[i]
            if raw_hashes is not None:
                close = '"' + "#" * raw_hashes
                j = line.find(close, i)
                if j < 0:
                    out.append(" " * (n - i))
                    i = n
                else:
                    out.append(" " * (j - i) + '"' + "#" * raw_hashes)
                    raw_hashes = None
                    i = j + len(close)
                continue
            if in_block:
                if line.startswith("*/", i):
                    in_block -= 1
                    out.append("  ")
                    i += 2
                elif line.startswith("/*", i):
                    in_block += 1
                    out.append("  ")
                    i += 2
                else:
                    out.append(" ")
                    i += 1
                continue
            if line.startswith("//", i):
                # keep // lint: markers findable from raw; code is blanked
                out.append(" " * (n - i))
                i = n
                continue
            if line.startswith("/*", i):
                in_block += 1
                out.append("  ")
                i += 2
                continue
            m = re.match(r'r(#*)"', line[i:])
            if m:
                raw_hashes = len(m.group(1))
                out.append("r" + m.group(1) + '"')
                i += len(m.group(0))
                continue
            if c == '"':
                j, buf = i + 1, []
                while j < n:
                    if line[j] == "\\":
                        j += 2
                        continue
                    if line[j] == '"':
                        break
                    buf.append(line[j])
                    j += 1
                if j >= n:  # multi-line plain strings don't occur here;
                    out.append(" " * (n - i))  # blank defensively
                    strings.append("".join(buf))
                    i = n
                else:
                    strings.append("".join(buf))
                    out.append('"' + " " * (j - i - 1) + '"')
                    i = j + 1
                continue
            if c == "'":
                # char literal iff it closes within a few chars; else lifetime
                m2 = re.match(r"'(\\.|[^'\\])'", line[i:])
                if m2:
                    out.append("'" + " " * (len(m2.group(0)) - 2) + "'")
                    i += len(m2.group(0))
                    continue
                out.append(c)
                i += 1
                continue
            out.append(c)
            i += 1
        rf.code.append("".join(out))
        rf.strings.append(strings)


# ---------------------------------------------------------------------------
# pass 2: test regions (attribute + brace depth over blanked code)
# ---------------------------------------------------------------------------


def _mark_tests(rf: RustFile) -> None:
    rf.is_test = [False] * len(rf.code)
    pending = False  # saw #[cfg(test)] / #[test], waiting for the item
    depth_end = 0  # while > 0 we are inside a test item
    depth = 0
    for i, text in enumerate(rf.code):
        opens = text.count("{")
        closes = text.count("}")
        if depth_end:
            rf.is_test[i] = True
            depth += opens - closes
            if depth < depth_end:
                depth_end = 0
            continue
        if pending:
            rf.is_test[i] = True
            if "{" in text:
                depth += opens - closes
                if opens > closes:  # body continues past this line
                    depth_end = depth  # closes when depth drops below
                pending = False
            elif text.strip().endswith(";") or _CFG_TEST_RE.search(rf.raw[i]):
                # item ended on one line, or another attribute stacked
                pending = not text.strip().endswith(";")
            continue
        if _CFG_TEST_RE.search(text_attr(rf, i)) or _TEST_ATTR_RE.search(
            text_attr(rf, i)
        ):
            rf.is_test[i] = True
            pending = True
            depth += opens - closes
            continue
        depth += opens - closes


def text_attr(rf: RustFile, i: int) -> str:
    """Attributes survive blanking (no strings/comments inside the ones we
    match), but read from blanked code so commented-out attrs don't count."""
    return rf.code[i]


# ---------------------------------------------------------------------------
# pass 3: function spans
# ---------------------------------------------------------------------------


def _find_functions(rf: RustFile) -> None:
    n = len(rf.code)
    for i in range(n):
        m = _FN_RE.search(rf.code[i])
        if not m:
            continue
        name = m.group(1)
        # find the opening brace of the body (skip `;` trait decls)
        j = i
        col = m.end()
        depth = 0
        opened = False
        end = None
        while j < n:
            text = rf.code[j]
            for k in range(col if j == i else 0, len(text)):
                ch = text[k]
                if ch == ";" and not opened and depth == 0:
                    j = n  # declaration without body
                    break
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    depth -= 1
                    if opened and depth == 0:
                        end = j
                        break
            if end is not None or j >= n:
                break
            j += 1
        if end is not None:
            rf.functions.append((name, i + 1, end + 1))


# ---------------------------------------------------------------------------
# pass 4: // lint: directives and hot-path ranges
# ---------------------------------------------------------------------------


def _collect_directives(rf: RustFile) -> None:
    open_hot: Optional[int] = None
    for i, line in enumerate(rf.raw):
        m = _LINT_RE.search(line)
        if not m:
            continue
        lineno = i + 1
        if m.group(1).startswith("allow"):
            rf.directives.append(
                Directive(
                    kind="allow",
                    line=lineno,
                    rule=m.group(2),
                    reason=m.group(3).strip(),
                )
            )
        elif m.group(1).startswith("fault-site"):
            rf.directives.append(
                Directive(kind="fault-site", line=lineno, rule=m.group(4))
            )
        elif m.group(1) == "hot-path":
            if open_hot is None:
                open_hot = lineno
            rf.directives.append(Directive(kind="hot-path", line=lineno))
        else:  # end-hot-path
            if open_hot is not None:
                rf.hot_ranges.append((open_hot, lineno))
                open_hot = None
            rf.directives.append(Directive(kind="end-hot-path", line=lineno))
    if open_hot is not None:
        rf.unterminated_hot = open_hot

//! Workloads: the paper's evaluation task families + serving load shapes.
//!
//! Prompt sets are exported at build time (`artifacts/eval_prompts.json`)
//! from the same SynthChat distributions the target was chat-tuned on —
//! dolly (open-ended), xsum (extreme summarization), cnndm (news
//! summarization) and the held-out wmt translation task that drives the
//! paper's Figure 3 OOD result. For load testing, a Poisson arrival
//! process and a prompt mixer are provided.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::json::Value;
use crate::rng::Pcg64;

/// Paper task names, in the order figures present them.
pub const TASKS: [&str; 3] = ["dolly", "cnndm", "xsum"];
/// The OOD task (Figure 3 / §A.5).
pub const OOD_TASK: &str = "wmt";

/// One evaluation example.
#[derive(Debug, Clone)]
pub struct EvalExample {
    pub prompt: Vec<u32>,
    /// Reference response from the task generator (quality checks only —
    /// SD correctness never depends on it).
    pub reference: Vec<u32>,
    pub topic: usize,
}

/// All exported task prompt sets.
#[derive(Debug)]
pub struct EvalSuite {
    tasks: BTreeMap<String, Vec<EvalExample>>,
}

impl EvalSuite {
    pub fn load(path: &std::path::Path) -> Result<EvalSuite> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Manifest(format!("cannot read {}: {e}", path.display())))?;
        Self::from_json(&Value::parse(&text)?)
    }

    pub fn from_json(v: &Value) -> Result<EvalSuite> {
        let obj = v.as_obj().ok_or_else(|| Error::Manifest("eval_prompts: not an object".into()))?;
        let mut tasks = BTreeMap::new();
        for (task, arr) in obj {
            let examples = arr
                .as_arr()
                .ok_or_else(|| Error::Manifest(format!("task {task}: not an array")))?
                .iter()
                .map(|e| {
                    let toks = |key: &str| -> Vec<u32> {
                        e.get(key)
                            .as_arr()
                            .map(|a| a.iter().map(|x| x.as_usize().unwrap_or(0) as u32).collect())
                            .unwrap_or_default()
                    };
                    EvalExample {
                        prompt: toks("prompt"),
                        reference: toks("reference"),
                        topic: e.get("topic").as_usize().unwrap_or(0),
                    }
                })
                .collect();
            tasks.insert(task.clone(), examples);
        }
        if tasks.is_empty() {
            return Err(Error::Manifest("eval_prompts: no tasks".into()));
        }
        Ok(EvalSuite { tasks })
    }

    pub fn task(&self, name: &str) -> Result<&[EvalExample]> {
        self.tasks
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Manifest(format!("no eval prompts for task '{name}'")))
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.keys().map(|s| s.as_str()).collect()
    }

    /// First `n` examples of a task (deterministic eval subsets).
    pub fn take(&self, name: &str, n: usize) -> Result<Vec<EvalExample>> {
        let all = self.task(name)?;
        Ok(all.iter().take(n).cloned().collect())
    }
}

/// A request in a serving trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Offset from trace start.
    pub arrival: std::time::Duration,
    pub task: String,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Poisson-arrival serving trace over a task mixture — the workload for
/// `examples/serve_benchmark.rs`.
pub struct TraceConfig {
    /// Mean arrival rate, requests/second.
    pub rate: f64,
    pub n_requests: usize,
    pub max_new: usize,
    /// (task, weight) mixture.
    pub mix: Vec<(String, f64)>,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 2.0,
            n_requests: 32,
            max_new: 32,
            mix: vec![
                ("dolly".to_string(), 0.5),
                ("cnndm".to_string(), 0.25),
                ("xsum".to_string(), 0.25),
            ],
            seed: 0,
        }
    }
}

pub fn build_trace(suite: &EvalSuite, cfg: &TraceConfig) -> Result<Vec<TraceRequest>> {
    let mut rng = Pcg64::with_stream(cfg.seed, 0x7ace);
    let weights: Vec<f32> = cfg.mix.iter().map(|(_, w)| *w as f32).collect();
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    let mut cursors: BTreeMap<&str, usize> = BTreeMap::new();
    for _ in 0..cfg.n_requests {
        // Exponential inter-arrival.
        t += -(1.0 - rng.next_f64()).ln() / cfg.rate;
        let ti = rng.categorical(&weights);
        let task = cfg.mix[ti].0.as_str();
        let examples = suite.task(task)?;
        let cursor = cursors.entry(task).or_insert(0);
        let ex = &examples[*cursor % examples.len()];
        *cursor += 1;
        out.push(TraceRequest {
            arrival: std::time::Duration::from_secs_f64(t),
            task: task.to_string(),
            prompt: ex.prompt.clone(),
            max_new: cfg.max_new,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_suite() -> EvalSuite {
        EvalSuite::from_json(
            &Value::parse(
                r#"{
                "dolly": [{"prompt": [1,3,9,4], "reference": [7,7], "topic": 0},
                          {"prompt": [1,3,8,4], "reference": [6], "topic": 1}],
                "xsum":  [{"prompt": [1,3,5,5,4], "reference": [9], "topic": 2}],
                "cnndm": [{"prompt": [1,3,5,6,4], "reference": [9], "topic": 2}],
                "wmt":   [{"prompt": [1,3,8,8,4], "reference": [5,5], "topic": 0}]
            }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn loads_tasks() {
        let s = tiny_suite();
        assert_eq!(s.task("dolly").unwrap().len(), 2);
        assert_eq!(s.task("dolly").unwrap()[0].prompt, vec![1, 3, 9, 4]);
        assert!(s.task("nope").is_err());
        assert_eq!(s.task_names(), vec!["cnndm", "dolly", "wmt", "xsum"]);
    }

    #[test]
    fn trace_is_sorted_and_mixed() {
        let s = tiny_suite();
        let cfg = TraceConfig { n_requests: 50, ..Default::default() };
        let trace = build_trace(&s, &cfg).unwrap();
        assert_eq!(trace.len(), 50);
        for w in trace.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals must be monotone");
        }
        let dolly = trace.iter().filter(|r| r.task == "dolly").count();
        assert!(dolly > 10 && dolly < 40, "mixture off: {dolly}/50 dolly");
    }

    #[test]
    fn trace_deterministic_per_seed() {
        let s = tiny_suite();
        let cfg = TraceConfig { n_requests: 10, ..Default::default() };
        let a = build_trace(&s, &cfg).unwrap();
        let b = build_trace(&s, &cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.task, y.task);
        }
    }
}

//! Workloads: the paper's evaluation task families + serving load shapes.
//!
//! Prompt sets are exported at build time (`artifacts/eval_prompts.json`)
//! from the same SynthChat distributions the target was chat-tuned on —
//! dolly (open-ended), xsum (extreme summarization), cnndm (news
//! summarization) and the held-out wmt translation task that drives the
//! paper's Figure 3 OOD result. For load testing, a Poisson arrival
//! process and a prompt mixer are provided.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::json::Value;
use crate::rng::Pcg64;

/// Paper task names, in the order figures present them.
pub const TASKS: [&str; 3] = ["dolly", "cnndm", "xsum"];
/// The OOD task (Figure 3 / §A.5).
pub const OOD_TASK: &str = "wmt";

/// One evaluation example.
#[derive(Debug, Clone)]
pub struct EvalExample {
    pub prompt: Vec<u32>,
    /// Reference response from the task generator (quality checks only —
    /// SD correctness never depends on it).
    pub reference: Vec<u32>,
    pub topic: usize,
}

/// All exported task prompt sets.
#[derive(Debug)]
pub struct EvalSuite {
    tasks: BTreeMap<String, Vec<EvalExample>>,
}

impl EvalSuite {
    pub fn load(path: &std::path::Path) -> Result<EvalSuite> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Manifest(format!("cannot read {}: {e}", path.display())))?;
        Self::from_json(&Value::parse(&text)?)
    }

    pub fn from_json(v: &Value) -> Result<EvalSuite> {
        let obj = v.as_obj().ok_or_else(|| Error::Manifest("eval_prompts: not an object".into()))?;
        let mut tasks = BTreeMap::new();
        for (task, arr) in obj {
            let examples = arr
                .as_arr()
                .ok_or_else(|| Error::Manifest(format!("task {task}: not an array")))?
                .iter()
                .map(|e| {
                    let toks = |key: &str| -> Vec<u32> {
                        e.get(key)
                            .as_arr()
                            .map(|a| a.iter().map(|x| x.as_usize().unwrap_or(0) as u32).collect())
                            .unwrap_or_default()
                    };
                    EvalExample {
                        prompt: toks("prompt"),
                        reference: toks("reference"),
                        topic: e.get("topic").as_usize().unwrap_or(0),
                    }
                })
                .collect();
            tasks.insert(task.clone(), examples);
        }
        if tasks.is_empty() {
            return Err(Error::Manifest("eval_prompts: no tasks".into()));
        }
        Ok(EvalSuite { tasks })
    }

    pub fn task(&self, name: &str) -> Result<&[EvalExample]> {
        self.tasks
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Manifest(format!("no eval prompts for task '{name}'")))
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.keys().map(|s| s.as_str()).collect()
    }

    /// First `n` examples of a task (deterministic eval subsets).
    pub fn take(&self, name: &str, n: usize) -> Result<Vec<EvalExample>> {
        let all = self.task(name)?;
        Ok(all.iter().take(n).cloned().collect())
    }
}

/// A request in a serving trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Offset from trace start.
    pub arrival: std::time::Duration,
    pub task: String,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Poisson-arrival serving trace over a task mixture — the workload for
/// `examples/serve_benchmark.rs`.
pub struct TraceConfig {
    /// Mean arrival rate, requests/second.
    pub rate: f64,
    pub n_requests: usize,
    pub max_new: usize,
    /// (task, weight) mixture.
    pub mix: Vec<(String, f64)>,
    /// (prompt_len, weight) mixture. Empty = natural prompt lengths;
    /// otherwise each request's prompt is stretched/truncated to a drawn
    /// target length ([`stretch_prompt`]) so admission behaves like a
    /// short-chat vs long-document mix instead of the near-uniform
    /// exported prompt lengths.
    pub prompt_len_mix: Vec<(usize, f64)>,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 2.0,
            n_requests: 32,
            max_new: 32,
            mix: vec![
                ("dolly".to_string(), 0.5),
                ("cnndm".to_string(), 0.25),
                ("xsum".to_string(), 0.25),
            ],
            prompt_len_mix: Vec::new(),
            seed: 0,
        }
    }
}

/// Parse a `task:weight,...` mixture spec (e.g. `dolly:0.5,cnndm:0.3`).
/// The OOD task is rejected outright: distillation seeds must never
/// contain wmt — that exclusion is exactly what makes wmt
/// out-of-distribution in the paper's Figure 3 protocol (§2.2 / §A.5).
pub fn parse_task_mix(spec: &str) -> Result<Vec<(String, f64)>> {
    let mut mix: Vec<(String, f64)> = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (task, weight) = part
            .split_once(':')
            .ok_or_else(|| Error::Cli(format!("task mix entry '{part}': expected task:weight")))?;
        let task = task.trim();
        let weight: f64 = weight
            .trim()
            .parse()
            .map_err(|_| Error::Cli(format!("task mix entry '{part}': bad weight")))?;
        if !weight.is_finite() || weight <= 0.0 {
            return Err(Error::Cli(format!("task mix entry '{part}': weight must be > 0")));
        }
        if task == OOD_TASK {
            return Err(Error::Cli(format!(
                "task '{OOD_TASK}' is the held-out OOD task and cannot seed distillation"
            )));
        }
        if mix.iter().any(|(t, _)| t == task) {
            return Err(Error::Cli(format!("task '{task}' appears twice in the mix")));
        }
        mix.push((task.to_string(), weight));
    }
    if mix.is_empty() {
        return Err(Error::Cli("empty task mix".into()));
    }
    Ok(mix)
}

/// Parse a `len:weight,...` prompt-length mixture spec (e.g.
/// `8:0.7,96:0.3` — a short-chat vs long-document serving mix). Lengths
/// are target prompt token counts (>= 1), weights must be positive, and a
/// length may appear only once.
pub fn parse_len_mix(spec: &str) -> Result<Vec<(usize, f64)>> {
    let mut mix: Vec<(usize, f64)> = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (len, weight) = part
            .split_once(':')
            .ok_or_else(|| Error::Cli(format!("len mix entry '{part}': expected len:weight")))?;
        let len: usize = len
            .trim()
            .parse()
            .map_err(|_| Error::Cli(format!("len mix entry '{part}': bad length")))?;
        let weight: f64 = weight
            .trim()
            .parse()
            .map_err(|_| Error::Cli(format!("len mix entry '{part}': bad weight")))?;
        if len == 0 {
            return Err(Error::Cli(format!("len mix entry '{part}': length must be >= 1")));
        }
        if !weight.is_finite() || weight <= 0.0 {
            return Err(Error::Cli(format!("len mix entry '{part}': weight must be > 0")));
        }
        if mix.iter().any(|(l, _)| *l == len) {
            return Err(Error::Cli(format!("length {len} appears twice in the mix")));
        }
        mix.push((len, weight));
    }
    if mix.is_empty() {
        return Err(Error::Cli("empty len mix".into()));
    }
    Ok(mix)
}

/// Build a prompt of exactly `target` tokens by cycling `base` (synthetic
/// long-document / clipped short-chat prompts for load shaping; every
/// token id stays in-vocab because it came from a real exported prompt).
/// An empty base stays empty — the caller surfaces that as a bad example.
pub fn stretch_prompt(base: &[u32], target: usize) -> Vec<u32> {
    if base.is_empty() {
        return Vec::new();
    }
    base.iter().copied().cycle().take(target).collect()
}

/// One distillation seed instruction drawn from the mix.
#[derive(Debug, Clone, PartialEq)]
pub struct SeedPrompt {
    /// Global position in the stream (becomes the record `seq_index`).
    pub index: u64,
    pub task: String,
    pub prompt: Vec<u32>,
    /// Target sampling temperature for this sequence, drawn from the
    /// paper's §3 grid.
    pub temperature: f32,
    /// Per-sequence sampler seed (decorrelates lanes, deterministically).
    pub sampling_seed: u64,
}

/// Deterministic distillation seed-instruction stream: same suite + mix +
/// temperature grid + seed ⇒ bit-identical prompt stream. That determinism
/// is what makes `specd distill` checkpoint/resume duplicate-free — the
/// writer records how many sequences are committed and the stream is
/// simply fast-forwarded past them ([`SeedStream::skip`]).
pub struct SeedStream<'a> {
    suite: &'a EvalSuite,
    mix: Vec<(String, f64)>,
    weights: Vec<f32>,
    temperatures: Vec<f32>,
    rng: Pcg64,
    cursors: BTreeMap<String, usize>,
    next_index: u64,
}

impl<'a> SeedStream<'a> {
    pub fn new(
        suite: &'a EvalSuite,
        mix: Vec<(String, f64)>,
        temperatures: Vec<f32>,
        seed: u64,
    ) -> Result<SeedStream<'a>> {
        if mix.is_empty() {
            return Err(Error::Manifest("seed stream: empty task mix".into()));
        }
        if temperatures.is_empty() {
            return Err(Error::Manifest("seed stream: empty temperature grid".into()));
        }
        for (task, weight) in &mix {
            if task == OOD_TASK {
                return Err(Error::Manifest(format!(
                    "seed stream: '{OOD_TASK}' is OOD-held-out and cannot seed distillation"
                )));
            }
            if !weight.is_finite() || *weight <= 0.0 {
                return Err(Error::Manifest(format!("seed stream: bad weight for '{task}'")));
            }
            if suite.task(task)?.is_empty() {
                return Err(Error::Manifest(format!("seed stream: task '{task}' has no prompts")));
            }
        }
        let weights = mix.iter().map(|(_, w)| *w as f32).collect();
        Ok(SeedStream {
            suite,
            mix,
            weights,
            temperatures,
            rng: Pcg64::with_stream(seed, 0x5eed),
            cursors: BTreeMap::new(),
            next_index: 0,
        })
    }

    /// Next seed instruction. The stream is infinite: prompts cycle per
    /// task while the task/temperature draws stay i.i.d. from the RNG.
    pub fn next_prompt(&mut self) -> SeedPrompt {
        let ti = self.rng.categorical(&self.weights);
        let task = self.mix[ti].0.clone();
        let examples = self.suite.task(&task).expect("tasks validated in new()");
        let cursor = self.cursors.entry(task.clone()).or_insert(0);
        let prompt = examples[*cursor % examples.len()].prompt.clone();
        *cursor += 1;
        let temperature =
            self.temperatures[self.rng.next_below(self.temperatures.len() as u64) as usize];
        let sampling_seed = self.rng.next_u64();
        let index = self.next_index;
        self.next_index += 1;
        SeedPrompt { index, task, prompt, temperature, sampling_seed }
    }

    /// Fast-forward past `n` prompts (resume: the dataset's committed
    /// prefix was generated from exactly these).
    pub fn skip(&mut self, n: u64) {
        for _ in 0..n {
            self.next_prompt();
        }
    }
}

pub fn build_trace(suite: &EvalSuite, cfg: &TraceConfig) -> Result<Vec<TraceRequest>> {
    let mut rng = Pcg64::with_stream(cfg.seed, 0x7ace);
    let weights: Vec<f32> = cfg.mix.iter().map(|(_, w)| *w as f32).collect();
    let len_weights: Vec<f32> = cfg.prompt_len_mix.iter().map(|(_, w)| *w as f32).collect();
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    let mut cursors: BTreeMap<&str, usize> = BTreeMap::new();
    for _ in 0..cfg.n_requests {
        // Exponential inter-arrival.
        t += -(1.0 - rng.next_f64()).ln() / cfg.rate;
        let ti = rng.categorical(&weights);
        let task = cfg.mix[ti].0.as_str();
        let examples = suite.task(task)?;
        let cursor = cursors.entry(task).or_insert(0);
        let ex = &examples[*cursor % examples.len()];
        *cursor += 1;
        let prompt = if cfg.prompt_len_mix.is_empty() {
            ex.prompt.clone()
        } else {
            let li = rng.categorical(&len_weights);
            stretch_prompt(&ex.prompt, cfg.prompt_len_mix[li].0)
        };
        out.push(TraceRequest {
            arrival: std::time::Duration::from_secs_f64(t),
            task: task.to_string(),
            prompt,
            max_new: cfg.max_new,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_suite() -> EvalSuite {
        EvalSuite::from_json(
            &Value::parse(
                r#"{
                "dolly": [{"prompt": [1,3,9,4], "reference": [7,7], "topic": 0},
                          {"prompt": [1,3,8,4], "reference": [6], "topic": 1}],
                "xsum":  [{"prompt": [1,3,5,5,4], "reference": [9], "topic": 2}],
                "cnndm": [{"prompt": [1,3,5,6,4], "reference": [9], "topic": 2}],
                "wmt":   [{"prompt": [1,3,8,8,4], "reference": [5,5], "topic": 0}]
            }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn loads_tasks() {
        let s = tiny_suite();
        assert_eq!(s.task("dolly").unwrap().len(), 2);
        assert_eq!(s.task("dolly").unwrap()[0].prompt, vec![1, 3, 9, 4]);
        assert!(s.task("nope").is_err());
        assert_eq!(s.task_names(), vec!["cnndm", "dolly", "wmt", "xsum"]);
    }

    #[test]
    fn trace_is_sorted_and_mixed() {
        let s = tiny_suite();
        let cfg = TraceConfig { n_requests: 50, ..Default::default() };
        let trace = build_trace(&s, &cfg).unwrap();
        assert_eq!(trace.len(), 50);
        for w in trace.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals must be monotone");
        }
        let dolly = trace.iter().filter(|r| r.task == "dolly").count();
        assert!(dolly > 10 && dolly < 40, "mixture off: {dolly}/50 dolly");
    }

    #[test]
    fn seed_stream_deterministic_per_seed() {
        let s = tiny_suite();
        let mix = parse_task_mix("dolly:0.5,cnndm:0.3,xsum:0.2").unwrap();
        let temps = vec![0.0f32, 0.3, 0.7, 1.0];
        let mut a = SeedStream::new(&s, mix.clone(), temps.clone(), 9).unwrap();
        let mut b = SeedStream::new(&s, mix.clone(), temps.clone(), 9).unwrap();
        let xs: Vec<SeedPrompt> = (0..64).map(|_| a.next_prompt()).collect();
        let ys: Vec<SeedPrompt> = (0..64).map(|_| b.next_prompt()).collect();
        assert_eq!(xs, ys, "same seed must give an identical prompt stream");
        // A different seed diverges (not a constant stream).
        let mut c = SeedStream::new(&s, mix, temps, 10).unwrap();
        let zs: Vec<SeedPrompt> = (0..64).map(|_| c.next_prompt()).collect();
        assert_ne!(xs, zs);
        // Indices are the global stream positions.
        assert_eq!(xs.iter().map(|p| p.index).collect::<Vec<_>>(),
                   (0..64).collect::<Vec<u64>>());
    }

    #[test]
    fn seed_stream_never_emits_wmt() {
        let s = tiny_suite();
        let mix = parse_task_mix("dolly:0.5,cnndm:0.3,xsum:0.2").unwrap();
        let mut stream = SeedStream::new(&s, mix, vec![0.0, 0.7], 0).unwrap();
        for _ in 0..256 {
            let p = stream.next_prompt();
            assert_ne!(p.task, OOD_TASK, "wmt is OOD-held-out and must never be seeded");
            assert!(TASKS.contains(&p.task.as_str()));
        }
        // And the OOD task cannot even be configured into the mix.
        assert!(parse_task_mix("wmt:1.0").is_err());
        assert!(parse_task_mix("dolly:0.5,wmt:0.5").is_err());
        assert!(SeedStream::new(&s, vec![("wmt".into(), 1.0)], vec![0.0], 0).is_err());
    }

    #[test]
    fn seed_stream_skip_matches_consumption() {
        let s = tiny_suite();
        let mix = parse_task_mix("dolly:1,xsum:1").unwrap();
        let temps = vec![0.0f32, 1.0];
        let mut a = SeedStream::new(&s, mix.clone(), temps.clone(), 3).unwrap();
        let full: Vec<SeedPrompt> = (0..10).map(|_| a.next_prompt()).collect();
        let mut b = SeedStream::new(&s, mix, temps, 3).unwrap();
        b.skip(5);
        let tail: Vec<SeedPrompt> = (0..5).map(|_| b.next_prompt()).collect();
        assert_eq!(tail, full[5..], "skip(n) == consuming n prompts");
    }

    #[test]
    fn parse_task_mix_rejects_garbage() {
        assert!(parse_task_mix("").is_err());
        assert!(parse_task_mix("dolly").is_err(), "missing weight");
        assert!(parse_task_mix("dolly:x").is_err(), "non-numeric weight");
        assert!(parse_task_mix("dolly:-1").is_err(), "negative weight");
        assert!(parse_task_mix("dolly:0").is_err(), "zero weight");
        assert!(parse_task_mix("dolly:0.5,dolly:0.5").is_err(), "duplicate task");
        let ok = parse_task_mix(" dolly:0.5 , cnndm:0.3 ").unwrap();
        assert_eq!(ok, vec![("dolly".to_string(), 0.5), ("cnndm".to_string(), 0.3)]);
    }

    #[test]
    fn seed_stream_requires_known_tasks() {
        let s = tiny_suite();
        assert!(SeedStream::new(&s, vec![("nope".into(), 1.0)], vec![0.0], 0).is_err());
        assert!(SeedStream::new(&s, vec![("dolly".into(), 1.0)], vec![], 0).is_err());
        assert!(SeedStream::new(&s, vec![], vec![0.0], 0).is_err());
    }

    #[test]
    fn parse_len_mix_rejects_garbage() {
        assert!(parse_len_mix("").is_err());
        assert!(parse_len_mix("8").is_err(), "missing weight");
        assert!(parse_len_mix("8:x").is_err(), "non-numeric weight");
        assert!(parse_len_mix("x:1").is_err(), "non-numeric length");
        assert!(parse_len_mix("0:1").is_err(), "zero length");
        assert!(parse_len_mix("8:0").is_err(), "zero weight");
        assert!(parse_len_mix("8:-1").is_err(), "negative weight");
        assert!(parse_len_mix("8:0.5,8:0.5").is_err(), "duplicate length");
        let ok = parse_len_mix(" 8:0.7 , 96:0.3 ").unwrap();
        assert_eq!(ok, vec![(8, 0.7), (96, 0.3)]);
    }

    #[test]
    fn stretch_prompt_cycles_and_truncates() {
        assert_eq!(stretch_prompt(&[1, 2, 3], 7), vec![1, 2, 3, 1, 2, 3, 1]);
        assert_eq!(stretch_prompt(&[1, 2, 3], 2), vec![1, 2]);
        assert_eq!(stretch_prompt(&[5], 4), vec![5, 5, 5, 5]);
        assert!(stretch_prompt(&[], 4).is_empty(), "empty base stays empty");
    }

    #[test]
    fn trace_len_mix_shapes_prompt_lengths() {
        let s = tiny_suite();
        let cfg = TraceConfig {
            n_requests: 120,
            prompt_len_mix: parse_len_mix("3:0.5,40:0.5").unwrap(),
            ..Default::default()
        };
        let trace = build_trace(&s, &cfg).unwrap();
        assert_eq!(trace.len(), 120);
        let short = trace.iter().filter(|r| r.prompt.len() == 3).count();
        let long = trace.iter().filter(|r| r.prompt.len() == 40).count();
        assert_eq!(short + long, 120, "every prompt stretched to a mix length");
        assert!(short > 30 && long > 30, "mixture off: {short}/{long}");
        // Stretched prompts cycle real exported token ids, never invent
        // them (tiny_suite's vocabulary of prompt tokens).
        let known: std::collections::BTreeSet<u32> = [1, 3, 4, 5, 6, 8, 9].into_iter().collect();
        let long_req = trace.iter().find(|r| r.prompt.len() == 40).unwrap();
        assert!(long_req.prompt.iter().all(|t| known.contains(t)), "tokens must stay in-vocab");
        // Deterministic per seed, and the natural-length default is intact.
        let again = build_trace(&s, &cfg).unwrap();
        assert_eq!(
            trace.iter().map(|r| r.prompt.len()).collect::<Vec<_>>(),
            again.iter().map(|r| r.prompt.len()).collect::<Vec<_>>()
        );
        let natural = build_trace(&s, &TraceConfig::default()).unwrap();
        assert!(natural.iter().all(|r| r.prompt.len() <= 5), "natural lengths untouched");
    }

    #[test]
    fn trace_deterministic_per_seed() {
        let s = tiny_suite();
        let cfg = TraceConfig { n_requests: 10, ..Default::default() };
        let a = build_trace(&s, &cfg).unwrap();
        let b = build_trace(&s, &cfg).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.task, y.task);
        }
    }
}

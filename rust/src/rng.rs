//! Deterministic RNG substrate (the `rand` crate is unavailable offline).
//!
//! PCG64 (O'Neill's PCG XSL RR 128/64) — small, fast, statistically solid,
//! and fully reproducible across platforms given a seed. Every stochastic
//! component in the serving stack (sampling, workload generation, property
//! tests) takes an explicit `Pcg64` so runs are replayable, which the
//! speculative-decoding equivalence tests rely on.

/// PCG XSL RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (used to decorrelate e.g. the
    /// draft sampler from the acceptance sampler).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Geometric-ish length in [lo, hi) (workload generators).
    pub fn geometric_len(&mut self, lo: usize, hi: usize, p_stop: f64) -> usize {
        let mut n = lo;
        while n + 1 < hi && self.next_f64() > p_stop {
            n += 1;
        }
        n
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        debug_assert!(total > 0.0, "categorical over zero mass");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1 // fp slack
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(7, 1);
        let mut b = Pcg64::with_stream(7, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg64::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(5);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Artifact bundle loading: `manifest.json`, `vocab.json`, HLO paths and
//! weight files produced by `make artifacts` (python/compile/aot.py).
//!
//! The manifest is the single source of truth the Rust side trusts about
//! the build-time world: architecture dims, KV/state vector lengths, the
//! canonical parameter order, per-model parameter counts and the measured
//! draft:target ratio `c` that enters the MBSU metric.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::Value;

/// One exported architecture (shared by all weight variants of that shape).
#[derive(Debug, Clone)]
pub struct ArchInfo {
    pub name: String,
    pub hlo_dir: String,
    pub n_layers: usize,
    pub n_heads: usize,
    pub hidden: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub vocab_size: usize,
    /// f32 elements of the KV region at the front of the state vector.
    pub kv_len: usize,
    /// total f32 elements of the state vector (kv + logits region).
    pub state_len: usize,
    pub param_order: Vec<String>,
    /// Batch sizes of the exported `[B, T]` entry points
    /// (`<entry>.b<B>.hlo.txt`). Empty on bundles exported before batched
    /// entries existed — the key is optional and the runtime then serves
    /// per-lane.
    pub batch_sizes: Vec<usize>,
}

/// One trained model (weights variant).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub arch: String,
    pub weights_rel: String,
    pub params: usize,
    /// params(model) / params(target) — the paper's relative latency proxy.
    pub c_ratio: f64,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab_file: String,
    pub vocab_size: usize,
    pub vocab_hash: String,
    /// entry point name -> token block size.
    pub entry_blocks: BTreeMap<String, usize>,
    pub archs: BTreeMap<String, ArchInfo>,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let root = PathBuf::from(dir);
        let text = std::fs::read_to_string(root.join("manifest.json")).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {}/manifest.json (run `make artifacts` first): {e}",
                dir
            ))
        })?;
        let v = Value::parse(&text)?;
        Self::from_value(root, &v)
    }

    pub fn from_value(root: PathBuf, v: &Value) -> Result<Manifest> {
        if v.req_str("format")? != "specd-artifacts-v1" {
            return Err(Error::Manifest(format!(
                "unsupported artifact format {:?}",
                v.get("format")
            )));
        }
        let vocab = v.get("vocab");
        let mut entry_blocks = BTreeMap::new();
        for (name, ep) in v
            .get("entry_points")
            .as_obj()
            .ok_or_else(|| Error::Manifest("missing entry_points".into()))?
        {
            entry_blocks.insert(name.clone(), ep.req_usize("block")?);
        }
        let mut archs = BTreeMap::new();
        for (name, a) in
            v.get("arch").as_obj().ok_or_else(|| Error::Manifest("missing arch".into()))?
        {
            let param_order = a
                .get("param_order")
                .as_arr()
                .ok_or_else(|| Error::Manifest("missing param_order".into()))?
                .iter()
                .map(|x| x.as_str().unwrap_or("").to_string())
                .collect();
            // Optional (absent on pre-batched bundles): tolerate missing
            // key and junk entries rather than rejecting an old bundle.
            let batch_sizes = a
                .get("batch_sizes")
                .as_arr()
                .map(|xs| xs.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            archs.insert(
                name.clone(),
                ArchInfo {
                    name: name.clone(),
                    hlo_dir: a.req_str("hlo_dir")?.to_string(),
                    n_layers: a.req_usize("n_layers")?,
                    n_heads: a.req_usize("n_heads")?,
                    hidden: a.req_usize("hidden")?,
                    head_dim: a.req_usize("head_dim")?,
                    max_seq: a.req_usize("max_seq")?,
                    vocab_size: a.req_usize("vocab_size")?,
                    kv_len: a.req_usize("kv_len")?,
                    state_len: a.req_usize("state_len")?,
                    param_order,
                    batch_sizes,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in
            v.get("models").as_obj().ok_or_else(|| Error::Manifest("missing models".into()))?
        {
            let arch = m.req_str("arch")?.to_string();
            if !archs.contains_key(&arch) {
                return Err(Error::Manifest(format!("model {name} references unknown arch {arch}")));
            }
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    arch,
                    weights_rel: m.req_str("weights")?.to_string(),
                    params: m.req_usize("params")?,
                    c_ratio: m.req_f64("c_ratio")?,
                },
            );
        }
        Ok(Manifest {
            root,
            vocab_file: vocab.req_str("file")?.to_string(),
            vocab_size: vocab.req_usize("size")?,
            vocab_hash: vocab.req_str("hash")?.to_string(),
            entry_blocks,
            archs,
            models,
        })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchInfo> {
        self.archs
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown architecture '{name}'")))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            Error::Manifest(format!(
                "unknown model '{name}' (available: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    pub fn hlo_path(&self, arch: &str, entry: &str) -> Result<PathBuf> {
        let a = self.arch(arch)?;
        if !self.entry_blocks.contains_key(entry) {
            return Err(Error::Manifest(format!("unknown entry point '{entry}'")));
        }
        Ok(self.root.join(&a.hlo_dir).join(format!("{entry}.hlo.txt")))
    }

    pub fn weights_path(&self, model: &str) -> Result<PathBuf> {
        Ok(self.root.join(&self.model(model)?.weights_rel))
    }

    pub fn vocab_path(&self) -> PathBuf {
        self.root.join(&self.vocab_file)
    }

    /// All draft model names (everything that is not the target arch),
    /// sorted — the checkpoint sweep in the Figure 2 bench iterates this.
    pub fn draft_models(&self) -> Vec<String> {
        self.models
            .values()
            .filter(|m| m.arch == "draft")
            .map(|m| m.name.clone())
            .collect()
    }
}

/// Convenience: does this path look like a complete artifact bundle?
pub fn bundle_exists(dir: &str) -> bool {
    Path::new(dir).join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Value {
        Value::parse(
            r#"{
            "format": "specd-artifacts-v1",
            "vocab": {"file": "vocab.json", "size": 384, "hash": "abc"},
            "entry_points": {"prefill": {"block": 32}, "verify": {"block": 8}, "decode": {"block": 1}},
            "arch": {
                "target": {"hlo_dir": "hlo/target", "n_layers": 6, "n_heads": 8,
                           "hidden": 128, "intermediate": 384, "head_dim": 16,
                           "max_seq": 256, "vocab_size": 384, "kv_len": 393216,
                           "state_len": 405504, "param_order": ["embed", "final_norm"],
                           "batch_sizes": [8]},
                "draft": {"hlo_dir": "hlo/draft", "n_layers": 2, "n_heads": 3,
                          "hidden": 24, "intermediate": 64, "head_dim": 8,
                          "max_seq": 256, "vocab_size": 384, "kv_len": 24576,
                          "state_len": 36864, "param_order": ["embed", "final_norm"]}
            },
            "models": {
                "target": {"arch": "target", "weights": "weights/target.bin",
                           "params": 1377920, "c_ratio": 1.0},
                "draft_base": {"arch": "draft", "weights": "weights/draft_base.bin",
                               "params": 23160, "c_ratio": 0.0168}
            }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_value(PathBuf::from("/tmp/x"), &sample_manifest()).unwrap();
        assert_eq!(m.entry_blocks["verify"], 8);
        assert_eq!(m.arch("draft").unwrap().kv_len, 24576);
        assert!((m.model("draft_base").unwrap().c_ratio - 0.0168).abs() < 1e-9);
        assert_eq!(m.draft_models(), vec!["draft_base".to_string()]);
        // batch_sizes is optional: present on target, absent on draft —
        // both parse (pre-batched bundles keep loading).
        assert_eq!(m.arch("target").unwrap().batch_sizes, vec![8]);
        assert!(m.arch("draft").unwrap().batch_sizes.is_empty());
    }

    #[test]
    fn paths_resolve() {
        let m = Manifest::from_value(PathBuf::from("/a"), &sample_manifest()).unwrap();
        assert_eq!(
            m.hlo_path("draft", "decode").unwrap(),
            PathBuf::from("/a/hlo/draft/decode.hlo.txt")
        );
        assert_eq!(m.weights_path("target").unwrap(), PathBuf::from("/a/weights/target.bin"));
    }

    #[test]
    fn unknown_names_fail() {
        let m = Manifest::from_value(PathBuf::from("/a"), &sample_manifest()).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.arch("nope").is_err());
        assert!(m.hlo_path("draft", "nope").is_err());
    }

    #[test]
    fn wrong_format_rejected() {
        let v = Value::parse(r#"{"format": "v999"}"#).unwrap();
        assert!(Manifest::from_value(PathBuf::from("/a"), &v).is_err());
    }
}

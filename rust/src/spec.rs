//! The speculative decoding engine: draft-γ-then-verify with KV rollback.
//!
//! Per block (one target run), following Leviathan et al. as deployed in
//! the paper's evaluation:
//!
//! 1. **draft sync** — feed the tokens the draft hasn't processed yet
//!    (1-2 tokens after the first block) in ONE draft call; its last
//!    logits row is the basis for proposal 0.
//! 2. **draft proposals** — sample γ tokens autoregressively; only γ-1
//!    decode calls are needed because proposal j's basis is the decode of
//!    t_{j-1} and the last proposed token is *not* pre-processed (if it
//!    survives verification the next block's sync ingests it). Total draft
//!    calls per block = γ, exactly the paper's c·γ cost model.
//! 3. **target verify** — one call processing [pending ++ drafted] (≤ γ+1
//!    ≤ the exported verify block of 8) yielding the γ+1 target
//!    distributions q_0..q_γ.
//! 4. **rejection sampling** — [`sampling::verify_block`]; on rejection the
//!    caches *roll back by length only* (the position-masked attention
//!    contract makes stale rows unreachable).
//!
//! The block is exposed both as a single [`SpecDecoder::step`] call and as
//! the per-phase methods [`SpecDecoder::begin_block`],
//! [`SpecDecoder::propose_round`] and [`SpecDecoder::commit_block`], which
//! [`crate::batch::BatchStep`] runs in lockstep across all active
//! sequences so every phase's PJRT executable is dispatched in one tight
//! loop. Near the context cap the per-block draft length shrinks
//! ([`shrunken_gamma`]) instead of finishing the sequence blocks early.
//!
//! The engine is single-sequence; the [`crate::coordinator`] interleaves
//! many sessions over it (iteration-level scheduling).

use crate::config::SamplingConfig;
use crate::error::{Error, Result};
use crate::kvcache::SeqCache;
use crate::metrics::SpecStats;
use crate::rng::Pcg64;
use crate::runtime::{topk_of_row, Entry, Model, SeqState, TopkRow};
use crate::sampling::{logits_to_probs, sample_token, verify_block};
use crate::tokenizer::EOS;

/// Engine configuration + model handles.
pub struct SpecDecoder<'a> {
    pub draft: &'a Model,
    pub target: &'a Model,
    pub gamma: usize,
}

/// Largest per-block draft length γ_eff ≤ `gamma` that still fits at
/// sequence length `l` with `np` target-pending tokens:
///
/// * the target verify advances to `l + γ_eff` and must also hold the
///   re-fed pending prefix (`np + γ_eff ≤ verify_block`),
/// * the draft advances to `l + γ_eff - 1` (sync to `l`, then γ_eff − 1
///   decode calls).
///
/// `0` means the sequence is at capacity and the caller finishes it. This
/// replaces the old all-or-nothing `l + 2(γ+1) ≥ max_seq` guard, which
/// silently finished sequences roughly two blocks before the real cap.
pub fn shrunken_gamma(
    gamma: usize,
    l: usize,
    np: usize,
    target_max_seq: usize,
    draft_max_seq: usize,
    verify_block: usize,
) -> usize {
    let t_room = target_max_seq.saturating_sub(l);
    let d_room = (draft_max_seq + 1).saturating_sub(l);
    let vb_room = verify_block.saturating_sub(np);
    gamma.min(t_room).min(d_room).min(vb_room)
}

/// In-flight state of one speculation block between phases: produced by
/// [`SpecDecoder::begin_block`], fed by γ_eff [`SpecDecoder::propose_round`]
/// calls, consumed by [`SpecDecoder::commit_block`]. Fields are private so
/// the phase ordering invariants can't be violated from outside.
pub struct BlockState {
    /// This block's draft length (≤ the decoder γ; shrunk near the cap).
    gamma: usize,
    /// Logits row the next proposal samples from.
    basis: Vec<f32>,
    drafted: Vec<u32>,
    draft_probs: Vec<Vec<f32>>,
}

impl BlockState {
    /// The per-block (possibly shrunken) draft length.
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// Proposal rounds completed so far (0..=gamma).
    pub fn proposed(&self) -> usize {
        self.drafted.len()
    }
}

/// Target top-k logit capture for one session (distillation mode). The
/// engine already reads back every verify logits row; capture is a
/// host-side top-k extraction over rows it would otherwise discard, so the
/// only added cost is the selection itself (tracked in `seconds` and
/// reported as capture overhead by `specd distill`).
#[derive(Debug, Clone, Default)]
pub struct LogitCapture {
    /// (id, logit) pairs kept per generated position.
    pub topk: usize,
    /// One row per generated token, aligned with [`SpecSession::generated`].
    pub rows: Vec<TopkRow>,
    /// Host wall seconds spent extracting top-k (the capture overhead).
    pub seconds: f64,
}

impl LogitCapture {
    /// Truncate to the delivered token count (the final block can overshoot
    /// a request's `max_new`, same as [`SpecStats::clip_to_delivered`]).
    pub fn clip_to(&mut self, delivered: usize) {
        self.rows.truncate(delivered);
    }
}

/// One in-flight sequence.
pub struct SpecSession {
    /// prompt ++ generated tokens (ground truth sequence).
    pub seq: Vec<u32>,
    pub prompt_len: usize,
    d_cache: SeqCache<SeqState>,
    t_cache: SeqCache<SeqState>,
    /// Last target logits row (prediction for position seq.len()) — only
    /// consulted when the target has no pending tokens (right after prefill).
    t_last_logits: Vec<f32>,
    /// Last draft logits row — consulted when the draft has no pending
    /// tokens (right after prefill, before the first speculation block).
    d_last_logits: Vec<f32>,
    pub stats: SpecStats,
    pub finished: bool,
    /// Target top-k capture sink; `None` (the serving default) costs nothing.
    pub capture: Option<LogitCapture>,
}

impl SpecSession {
    pub fn generated(&self) -> &[u32] {
        &self.seq[self.prompt_len..]
    }

    /// Enable target top-k logit capture for this session (distillation
    /// dataset generation). Must be called before the first block; `k = 0`
    /// leaves capture off.
    pub fn enable_capture(&mut self, topk: usize) {
        if topk > 0 {
            self.capture = Some(LogitCapture { topk, ..LogitCapture::default() });
        }
    }
}

impl<'a> SpecDecoder<'a> {
    pub fn new(draft: &'a Model, target: &'a Model, gamma: usize) -> Result<Self> {
        let verify_block_size = target.arch.block(Entry::Verify);
        if gamma + 1 > verify_block_size {
            return Err(Error::msg(format!(
                "gamma {gamma} needs verify block >= {} (exported: {verify_block_size})",
                gamma + 1
            )));
        }
        if gamma == 0 {
            return Err(Error::msg("gamma must be >= 1"));
        }
        Ok(SpecDecoder { draft, target, gamma })
    }

    /// Prefill both models on the prompt.
    pub fn start(&self, prompt: &[u32]) -> Result<SpecSession> {
        if prompt.is_empty() {
            return Err(Error::msg("empty prompt"));
        }
        let mut stats = SpecStats::default();
        let (t_state, t_logits) = self.target.prefill_prompt(prompt)?;
        let (d_state, d_logits) = self.draft.prefill_prompt(prompt)?;
        let pf_block = self.target.arch.block(Entry::Prefill);
        stats.target_calls += prompt.len().div_ceil(pf_block);
        stats.draft_calls += prompt.len().div_ceil(self.draft.arch.block(Entry::Prefill));

        let mut t_cache = SeqCache::new(t_state, self.target.max_seq());
        t_cache.advance(prompt.len())?;
        let mut d_cache = SeqCache::new(d_state, self.draft.max_seq());
        d_cache.advance(prompt.len())?;

        Ok(SpecSession {
            seq: prompt.to_vec(),
            prompt_len: prompt.len(),
            d_cache,
            t_cache,
            t_last_logits: t_logits,
            d_last_logits: d_logits,
            stats,
            finished: false,
            capture: None,
        })
    }

    /// Feed the draft everything it hasn't processed and return its last
    /// logits row (the proposal-0 basis). At most one model call; zero
    /// right after prefill, when the stored prefill row is the basis.
    fn sync_draft(&self, s: &mut SpecSession) -> Result<Vec<f32>> {
        let l = s.seq.len();
        let d_len = s.d_cache.len();
        if d_len == l {
            return Ok(s.d_last_logits.clone());
        }
        let pending = &s.seq[d_len..l];
        let vb = self.draft.arch.block(Entry::Verify);
        debug_assert!(pending.len() <= vb, "draft pending {} > verify block {vb}", pending.len());
        let entry = if pending.len() == 1 { Entry::Decode } else { Entry::Verify };
        let state = s.d_cache.take_state()?;
        let (state, logits) = self.draft.run(entry, state, pending, d_len)?;
        s.d_cache.put_state(state);
        s.d_cache.advance(pending.len())?;
        s.stats.draft_calls += 1;
        let v = self.draft.vocab_size();
        let off = (pending.len() - 1) * v;
        s.d_last_logits = logits[off..off + v].to_vec();
        Ok(s.d_last_logits.clone())
    }

    /// This session's per-block draft length right now (0 = at capacity).
    fn effective_gamma(&self, s: &SpecSession) -> usize {
        let l = s.seq.len();
        let np = l - s.t_cache.len();
        shrunken_gamma(
            self.gamma,
            l,
            np,
            self.target.max_seq(),
            self.draft.max_seq(),
            self.target.arch.block(Entry::Verify),
        )
    }

    /// Phase 1 — draft sync. Picks the per-block draft length (shrunk near
    /// the context cap) and feeds the draft everything it hasn't processed.
    /// Returns `None` — and marks the session finished — when not even a
    /// γ_eff = 1 block fits (or the session already finished).
    pub fn begin_block(&self, s: &mut SpecSession) -> Result<Option<BlockState>> {
        if s.finished {
            return Ok(None);
        }
        let gamma = self.effective_gamma(s);
        if gamma == 0 {
            s.finished = true;
            return Ok(None);
        }
        let basis = self.sync_draft(s)?;
        Ok(Some(BlockState {
            gamma,
            basis,
            drafted: Vec::with_capacity(gamma),
            draft_probs: Vec::with_capacity(gamma),
        }))
    }

    /// Phase 2 — one proposal round: sample draft token j from the current
    /// basis, then run one draft decode for the next basis — except after
    /// the last round (if the last token survives verification, the next
    /// block's sync ingests it; that keeps draft calls per block at γ_eff).
    pub fn propose_round(
        &self,
        s: &mut SpecSession,
        b: &mut BlockState,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<()> {
        debug_assert!(b.drafted.len() < b.gamma, "proposal round past gamma");
        let v = self.target.vocab_size();
        let p = logits_to_probs(&b.basis, cfg);
        let t = sample_token(&p, cfg, rng);
        b.drafted.push(t);
        b.draft_probs.push(p);
        if b.drafted.len() < b.gamma {
            let state = s.d_cache.take_state()?;
            let (state, logits) = self.draft.run(Entry::Decode, state, &[t], s.d_cache.len())?;
            s.d_cache.put_state(state);
            s.d_cache.advance(1)?;
            s.stats.draft_calls += 1;
            b.basis = logits[..v].to_vec();
        }
        Ok(())
    }

    /// Phases 3 + 4 — one target verify over [pending ++ drafted], then
    /// rejection sampling, cache rollback and EOS handling. Returns the
    /// emitted tokens (1..=γ_eff+1, never empty).
    pub fn commit_block(
        &self,
        s: &mut SpecSession,
        b: BlockState,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<Vec<u32>> {
        let BlockState { gamma, drafted, draft_probs, .. } = b;
        debug_assert_eq!(drafted.len(), gamma, "commit before all proposal rounds");
        let l = s.seq.len();
        let v = self.target.vocab_size();
        s.stats.drafted += gamma;

        // 3. — one target verify over [pending ++ drafted].
        let t_len = s.t_cache.len();
        let pending_t: Vec<u32> = s.seq[t_len..l].to_vec();
        let mut fed = pending_t.clone();
        fed.extend_from_slice(&drafted);
        debug_assert!(fed.len() <= self.target.arch.block(Entry::Verify));
        let state = s.t_cache.take_state()?;
        let (state, t_logits) = self.target.run(Entry::Verify, state, &fed, t_len)?;
        s.t_cache.put_state(state);
        s.t_cache.advance(fed.len())?;
        s.stats.target_calls += 1;
        s.stats.blocks += 1;

        // Assemble q_0..q_gamma.
        let np = pending_t.len();
        let row = |i: usize| -> &[f32] { &t_logits[i * v..(i + 1) * v] };
        let mut target_probs: Vec<Vec<f32>> = Vec::with_capacity(gamma + 1);
        for j in 0..=gamma {
            let probs = if j == 0 && np == 0 {
                logits_to_probs(&s.t_last_logits, cfg)
            } else {
                logits_to_probs(row(np + j - 1), cfg)
            };
            target_probs.push(probs);
        }

        // 4. — rejection sampling + rollback.
        let out = verify_block(&draft_probs, &target_probs, &drafted, rng);
        let k = out.accepted;
        s.stats.accepted += k;

        // Valid processed positions: target saw pending + all gamma drafted,
        // but only the first k drafted survive; the draft processed only the
        // first gamma-1 drafted tokens.
        s.t_cache.rollback_to(l + k)?;
        s.d_cache.rollback_to(l + k.min(gamma.saturating_sub(1)))?;

        let mut emitted: Vec<u32> = drafted[..k].to_vec();
        emitted.push(out.next_token);
        s.stats.generated += emitted.len();

        // EOS: truncate at the first EOS (inclusive) and finish.
        if let Some(eos_at) = emitted.iter().position(|&t| t == EOS) {
            emitted.truncate(eos_at + 1);
            // Roll validity back to the kept prefix.
            let keep = l + emitted.len();
            s.t_cache.rollback_to(s.t_cache.len().min(keep))?;
            s.d_cache.rollback_to(s.d_cache.len().min(keep))?;
            s.finished = true;
        }
        // Distillation capture: emitted[j] was verified/sampled against
        // q_j, whose raw logits row the verify call already returned
        // (position 0 right after prefill reuses the stored prefill row).
        // Runs after the EOS truncation so rows stay aligned with the kept
        // tokens.
        if let Some(cap) = s.capture.as_mut() {
            let t0 = std::time::Instant::now();
            for j in 0..emitted.len() {
                let raw: &[f32] =
                    if j == 0 && np == 0 { &s.t_last_logits } else { row(np + j - 1) };
                cap.rows.push(topk_of_row(raw, cap.topk));
            }
            cap.seconds += t0.elapsed().as_secs_f64();
        }
        s.seq.extend_from_slice(&emitted);
        Ok(emitted)
    }

    /// Run one speculation block; returns the tokens emitted (empty only
    /// when the session is finished or at capacity). Single-sequence
    /// composition of the phase methods — the batch scheduler runs the
    /// same phases in lockstep across sequences, consuming each lane's
    /// RNG in the same order, so batched and direct output match.
    pub fn step(
        &self,
        s: &mut SpecSession,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<Vec<u32>> {
        let Some(mut b) = self.begin_block(s)? else {
            return Ok(Vec::new());
        };
        for _ in 0..b.gamma {
            self.propose_round(s, &mut b, cfg, rng)?;
        }
        self.commit_block(s, b, cfg, rng)
    }

    /// Convenience driver: generate until EOS / max_new / capacity.
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<(Vec<u32>, SpecStats)> {
        let mut session = self.start(prompt)?;
        while !session.finished && session.generated().len() < max_new {
            let emitted = self.step(&mut session, cfg, rng)?;
            if emitted.is_empty() {
                break;
            }
        }
        let mut out = session.generated().to_vec();
        out.truncate(max_new);
        // The final block can overshoot max_new; the reported counters must
        // describe the *delivered* tokens or block efficiency inflates.
        session.stats.clip_to_delivered(out.len());
        Ok((out, session.stats))
    }
}

#[cfg(test)]
mod tests {
    // The engine needs compiled artifacts; its integration tests live in
    // rust/tests/spec_equivalence.rs. Here we pin the pure bookkeeping.
    use super::{shrunken_gamma, LogitCapture};
    use crate::metrics::SpecStats;
    use crate::runtime::TopkRow;

    #[test]
    fn stats_default_zero() {
        let s = SpecStats::default();
        assert_eq!(s.block_efficiency(), 0.0);
        assert_eq!(s.acceptance_rate(), 0.0);
    }

    #[test]
    fn shrunken_gamma_full_when_room() {
        // Far from every cap: the configured gamma is used unchanged.
        assert_eq!(shrunken_gamma(3, 10, 1, 256, 256, 8), 3);
        assert_eq!(shrunken_gamma(5, 0, 0, 256, 256, 8), 5);
    }

    #[test]
    fn shrunken_gamma_target_cap_binds() {
        // Target can only advance max_seq - l more positions.
        assert_eq!(shrunken_gamma(5, 254, 1, 256, 512, 8), 2);
        assert_eq!(shrunken_gamma(5, 255, 1, 256, 512, 8), 1);
        assert_eq!(shrunken_gamma(5, 256, 1, 256, 512, 8), 0, "at capacity");
    }

    #[test]
    fn shrunken_gamma_draft_cap_binds() {
        // Draft advances to l + gamma - 1, so it allows one extra position.
        assert_eq!(shrunken_gamma(5, 254, 1, 512, 256, 8), 3);
        assert_eq!(shrunken_gamma(5, 256, 1, 512, 256, 8), 1, "sync-only block");
        assert_eq!(shrunken_gamma(5, 257, 1, 512, 256, 8), 0);
    }

    #[test]
    fn shrunken_gamma_verify_block_binds() {
        // The verify call re-feeds np pending tokens alongside the draft.
        assert_eq!(shrunken_gamma(5, 10, 4, 256, 256, 8), 4);
        assert_eq!(shrunken_gamma(5, 10, 8, 256, 256, 8), 0);
    }

    #[test]
    fn capture_clip_truncates_rows_only() {
        let mut cap = LogitCapture { topk: 2, rows: Vec::new(), seconds: 0.25 };
        for i in 0..5u32 {
            cap.rows.push(TopkRow { ids: vec![i, i + 1], logits: vec![1.0, 0.5] });
        }
        cap.clip_to(3);
        assert_eq!(cap.rows.len(), 3);
        assert_eq!(cap.rows[2].ids, vec![2, 3]);
        // Never grows, and the overhead accounting is untouched.
        cap.clip_to(10);
        assert_eq!(cap.rows.len(), 3);
        assert!((cap.seconds - 0.25).abs() < 1e-12);
    }

    #[test]
    fn shrunken_gamma_never_exceeds_configured() {
        for l in 0..300 {
            let g = shrunken_gamma(3, l, 1, 256, 256, 8);
            assert!(g <= 3);
            // Monotone non-increasing in l once caps start binding.
            assert!(g >= shrunken_gamma(3, l + 1, 1, 256, 256, 8));
        }
    }
}

//! The speculative decoding engine: draft-γ-then-verify with KV rollback.
//!
//! Per block (one target run), following Leviathan et al. as deployed in
//! the paper's evaluation:
//!
//! 1. **draft sync** — feed the tokens the draft hasn't processed yet
//!    (1-2 tokens after the first block) in ONE draft call; its last
//!    logits row is the basis for proposal 0.
//! 2. **draft proposals** — sample γ tokens autoregressively; only γ-1
//!    decode calls are needed because proposal j's basis is the decode of
//!    t_{j-1} and the last proposed token is *not* pre-processed (if it
//!    survives verification the next block's sync ingests it). Total draft
//!    calls per block = γ, exactly the paper's c·γ cost model.
//! 3. **target verify** — one call processing [pending ++ drafted] (≤ γ+1
//!    ≤ the exported verify block of 8) yielding the γ+1 target
//!    distributions q_0..q_γ.
//! 4. **rejection sampling** — [`sampling::verify_block`]; on rejection the
//!    caches *roll back by length only* (the position-masked attention
//!    contract makes stale rows unreachable).
//!
//! The block is exposed both as a single [`SpecDecoder::step`] call and as
//! the per-phase methods [`SpecDecoder::begin_block`],
//! [`SpecDecoder::propose_round`] and [`SpecDecoder::commit_block`], which
//! [`crate::batch::BatchStep`] runs in lockstep across all active
//! sequences so every phase's PJRT executable is dispatched in one tight
//! loop. Near the context cap the per-block draft length shrinks
//! ([`shrunken_gamma`]) instead of finishing the sequence blocks early.
//!
//! ## Fused batched dispatch
//!
//! When the bundle exports batched `[B, T]` entry points, a
//! [`BatchedCtx`] (one [`StateArena`] per model) turns each lockstep
//! phase into a SINGLE PJRT dispatch over every adopted lane:
//! [`SpecDecoder::begin_block_batch`], [`SpecDecoder::propose_round_batch`]
//! and [`SpecDecoder::commit_block_batch`]. Sessions release their lanes
//! on every exit path ([`SpecDecoder::release`]). Each lane's RNG is
//! consumed in exactly the single-sequence order (γ proposal samples,
//! then the verification draws), so fused output token-matches the
//! direct engine.
//!
//! ## Batched admission waves (direct-to-lane prefill)
//!
//! Admission is fused too: a [`PrefillWave`] chunk-locksteps N queued
//! prompts through the batched PREFILL entry *directly into freshly
//! allocated arena lanes* ([`SpecDecoder::begin_wave`] →
//! [`SpecDecoder::wave_step`] → [`SpecDecoder::finish_wave`], or the
//! one-shot [`SpecDecoder::admit_wave`]). Ragged prompt lengths are
//! handled by the per-lane `pos[B]`/`active_mask[B]` contract: a lane
//! drops out of the dispatch once its prompt is exhausted and its state
//! (final-chunk logits rows included) passes through bit-for-bit until
//! the wave drains. Admitting N prompts therefore costs
//! O(ceil(L_max / prefill_block)) fused dispatches per model instead of
//! O(Σ ceil(L_i / prefill_block)) sequential ones — and ZERO pack
//! dispatches, no owned-state allocation and no full-state host
//! round-trip (the pre-wave path was prefill-owned-then-pack via
//! [`SpecDecoder::start`] + [`SpecDecoder::adopt`], which remains the
//! fallback when the arenas are full or the bundle is per-lane only).
//! [`SpecDecoder::wave_step`] takes a token budget so drivers can
//! interleave bounded slices of admission prefill with speculation
//! blocks for resident lanes (Sarathi-style chunked prefill: the
//! TTFT-vs-ITL trade-off becomes an explicit knob).
//!
//! ## Degraded target-only decoding
//!
//! When the draft model carries a circuit breaker
//! ([`crate::runtime::Model::set_breaker`]) and the circuit is open, the
//! engine keeps serving with γ = 0 blocks: no draft work, one exact
//! target sample per block ([`sampling::verify_block`] with an empty
//! draft set degenerates to plain sampling from q_0, so the output
//! distribution is unchanged — only the block efficiency drops to 1.0).
//! A half-open circuit grants one block a probe; on success the draft
//! cache catches up one verify-block of backlog per block (bounded
//! per-block dispatch cost) with γ = 0 blocks covering the gap, then
//! speculation resumes. Without a breaker, draft failures propagate
//! exactly as before.
//!
//! The engine is single-sequence; the [`crate::coordinator`] interleaves
//! many sessions over it (iteration-level scheduling).

use crate::batch::Lane;
use crate::config::SamplingConfig;
use crate::error::{Error, Result};
use crate::faults::BreakerState;
use crate::kvcache::SeqCache;
use crate::metrics::SpecStats;
use crate::rng::Pcg64;
use crate::runtime::{topk_of_row, Entry, LaneCall, Model, SeqState, StateArena, TopkRow};
use crate::sampling::{logits_to_probs, sample_token, verify_block};
use crate::tokenizer::EOS;

/// Engine configuration + model handles.
pub struct SpecDecoder<'a> {
    pub draft: &'a Model,
    pub target: &'a Model,
    pub gamma: usize,
}

/// Largest per-block draft length γ_eff ≤ `gamma` that still fits at
/// sequence length `l` with `np` target-pending tokens:
///
/// * the target verify advances to `l + γ_eff` and must also hold the
///   re-fed pending prefix (`np + γ_eff ≤ verify_block`),
/// * the draft advances to `l + γ_eff - 1` (sync to `l`, then γ_eff − 1
///   decode calls).
///
/// `0` means the sequence is at capacity and the caller finishes it. This
/// replaces the old all-or-nothing `l + 2(γ+1) ≥ max_seq` guard, which
/// silently finished sequences roughly two blocks before the real cap.
pub fn shrunken_gamma(
    gamma: usize,
    l: usize,
    np: usize,
    target_max_seq: usize,
    draft_max_seq: usize,
    verify_block: usize,
) -> usize {
    let t_room = target_max_seq.saturating_sub(l);
    let d_room = (draft_max_seq + 1).saturating_sub(l);
    let vb_room = verify_block.saturating_sub(np);
    gamma.min(t_room).min(d_room).min(vb_room)
}

/// In-flight state of one speculation block between phases: produced by
/// [`SpecDecoder::begin_block`], fed by γ_eff [`SpecDecoder::propose_round`]
/// calls, consumed by [`SpecDecoder::commit_block`]. Fields are private so
/// the phase ordering invariants can't be violated from outside.
pub struct BlockState {
    /// This block's draft length (≤ the decoder γ; shrunk near the cap).
    gamma: usize,
    /// Logits row the next proposal samples from.
    basis: Vec<f32>,
    drafted: Vec<u32>,
    draft_probs: Vec<Vec<f32>>,
}

impl BlockState {
    /// A γ = 0 target-only block: no draft work, one exact target
    /// sample. Used while the draft circuit is open or the draft cache
    /// is still catching up after a degraded stretch.
    fn degraded() -> BlockState {
        BlockState { gamma: 0, basis: Vec::new(), drafted: Vec::new(), draft_probs: Vec::new() }
    }

    /// The per-block (possibly shrunken) draft length.
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// Proposal rounds completed so far (0..=gamma).
    pub fn proposed(&self) -> usize {
        self.drafted.len()
    }
}

/// Target top-k logit capture for one session (distillation mode). The
/// engine already reads back every verify logits row; capture is a
/// host-side top-k extraction over rows it would otherwise discard, so the
/// only added cost is the selection itself (tracked in `seconds` and
/// reported as capture overhead by `specd distill`).
#[derive(Debug, Clone, Default)]
pub struct LogitCapture {
    /// (id, logit) pairs kept per generated position.
    pub topk: usize,
    /// One row per generated token, aligned with [`SpecSession::generated`].
    pub rows: Vec<TopkRow>,
    /// Host wall seconds spent extracting top-k (the capture overhead).
    pub seconds: f64,
}

impl LogitCapture {
    /// Truncate to the delivered token count (the final block can overshoot
    /// a request's `max_new`, same as [`SpecStats::clip_to_delivered`]).
    pub fn clip_to(&mut self, delivered: usize) {
        self.rows.truncate(delivered);
    }
}

/// One in-flight sequence.
pub struct SpecSession {
    /// prompt ++ generated tokens (ground truth sequence).
    pub seq: Vec<u32>,
    pub prompt_len: usize,
    d_cache: SeqCache<SeqState>,
    t_cache: SeqCache<SeqState>,
    /// Last target logits row (prediction for position seq.len()) — only
    /// consulted when the target has no pending tokens (right after prefill).
    t_last_logits: Vec<f32>,
    /// Last draft logits row — consulted when the draft has no pending
    /// tokens (right after prefill, before the first speculation block).
    d_last_logits: Vec<f32>,
    /// Reusable readback buffers for this session's draft/target calls —
    /// the steady-state decode path allocates no fresh logits vectors.
    d_logits_buf: Vec<f32>,
    t_logits_buf: Vec<f32>,
    pub stats: SpecStats,
    pub finished: bool,
    /// Target top-k capture sink; `None` (the serving default) costs nothing.
    pub capture: Option<LogitCapture>,
    /// Flight-recorder request ID for per-block trace marks (0 = untraced;
    /// the coordinator/datagen set it after adopting the session).
    pub trace_id: u64,
}

impl SpecSession {
    pub fn generated(&self) -> &[u32] {
        &self.seq[self.prompt_len..]
    }

    /// Enable target top-k logit capture for this session (distillation
    /// dataset generation). Must be called before the first block; `k = 0`
    /// leaves capture off.
    pub fn enable_capture(&mut self, topk: usize) {
        if topk > 0 {
            self.capture = Some(LogitCapture { topk, ..LogitCapture::default() });
        }
    }

    /// Whether this session's device state lives in a shared
    /// [`StateArena`] (fused batched dispatch) rather than in privately
    /// owned buffers.
    pub fn lane_mode(&self) -> bool {
        matches!(self.d_cache.state, Some(SeqState::Lane(_)))
    }

    fn d_lane(&self) -> Option<usize> {
        self.d_cache.state.as_ref().and_then(|s| s.lane())
    }

    fn t_lane(&self) -> Option<usize> {
        self.t_cache.state.as_ref().and_then(|s| s.lane())
    }
}

/// Shared fused-dispatch context: one device [`StateArena`] per model.
/// Created once per scheduler via [`SpecDecoder::batched_ctx`] when the
/// loaded bundle exports batched entry points; `None` otherwise and every
/// phase falls back to per-lane dispatch.
pub struct BatchedCtx {
    pub draft: StateArena,
    pub target: StateArena,
}

impl BatchedCtx {
    /// Free adopted-lane capacity (the min across the two arenas).
    pub fn available(&self) -> usize {
        self.draft.ledger.available().min(self.target.ledger.available())
    }
}

/// One prompt's slice of an in-flight admission wave: the prompt and the
/// two arena lanes (draft + target) it prefills into, allocated up front
/// so the wave owns its capacity for its whole lifetime.
struct WaveEntry {
    prompt: Vec<u32>,
    d_lane: usize,
    t_lane: usize,
}

/// An in-flight **batched admission wave**: N queued prompts
/// chunk-locksteped through the batched PREFILL entry directly into
/// arena lanes. All wave prompts start at position 0, so one shared
/// cursor drives the lockstep; a lane whose (shorter) prompt is
/// exhausted simply drops out of later dispatches and its state — final
/// logits rows included — passes through untouched until the wave
/// drains. Created by [`SpecDecoder::begin_wave`], advanced by
/// [`SpecDecoder::wave_step`] (budgeted, resumable across scheduler
/// iterations), consumed by [`SpecDecoder::finish_wave`]; on any
/// dispatch error the wave must be released via
/// [`SpecDecoder::abort_wave`] or its lanes leak.
pub struct PrefillWave {
    entries: Vec<WaveEntry>,
    /// Shared lockstep cursor: the next chunk starts here.
    pos: usize,
    /// Longest prompt in the wave (the cursor's end).
    max_len: usize,
    /// The prefill entry block (shared by draft and target).
    block: usize,
}

impl PrefillWave {
    /// Prompts (= lane pairs) in this wave.
    pub fn lanes(&self) -> usize {
        self.entries.len()
    }

    /// Whether every prompt is fully prefilled.
    pub fn done(&self) -> bool {
        self.pos >= self.max_len
    }

    /// Total prompt tokens across the wave.
    pub fn total_tokens(&self) -> usize {
        self.entries.iter().map(|e| e.prompt.len()).sum()
    }

    /// Prompt tokens not yet prefilled.
    pub fn remaining_tokens(&self) -> usize {
        self.entries.iter().map(|e| e.prompt.len().saturating_sub(self.pos)).sum()
    }

    /// Chunk dispatches per model still needed to drain the wave —
    /// O(ceil(L_max / block)), independent of the wave width.
    pub fn remaining_chunks(&self) -> usize {
        self.max_len.saturating_sub(self.pos).div_ceil(self.block)
    }
}

impl<'a> SpecDecoder<'a> {
    pub fn new(draft: &'a Model, target: &'a Model, gamma: usize) -> Result<Self> {
        let verify_block_size = target.arch.block(Entry::Verify);
        if gamma + 1 > verify_block_size {
            return Err(Error::msg(format!(
                "gamma {gamma} needs verify block >= {} (exported: {verify_block_size})",
                gamma + 1
            )));
        }
        if gamma == 0 {
            return Err(Error::msg("gamma must be >= 1"));
        }
        Ok(SpecDecoder { draft, target, gamma })
    }

    /// Prefill both models on the prompt.
    pub fn start(&self, prompt: &[u32]) -> Result<SpecSession> {
        if prompt.is_empty() {
            return Err(Error::msg("empty prompt"));
        }
        let mut stats = SpecStats::default();
        let (t_state, t_logits) = self.target.prefill_prompt(prompt)?;
        let (d_state, d_logits) = self.draft.prefill_prompt(prompt)?;
        let pf_block = self.target.arch.block(Entry::Prefill);
        stats.target_calls += prompt.len().div_ceil(pf_block);
        stats.draft_calls += prompt.len().div_ceil(self.draft.arch.block(Entry::Prefill));

        let mut t_cache = SeqCache::new(t_state, self.target.max_seq());
        t_cache.advance(prompt.len())?;
        let mut d_cache = SeqCache::new(d_state, self.draft.max_seq());
        d_cache.advance(prompt.len())?;

        Ok(SpecSession {
            seq: prompt.to_vec(),
            prompt_len: prompt.len(),
            d_cache,
            t_cache,
            t_last_logits: t_logits,
            d_last_logits: d_logits,
            d_logits_buf: Vec::new(),
            t_logits_buf: Vec::new(),
            stats,
            finished: false,
            capture: None,
            trace_id: 0,
        })
    }

    /// Total PJRT executable launches issued through this decoder's two
    /// models so far (the scheduler's dispatch-count metric reads deltas).
    pub fn dispatch_count(&self) -> u64 {
        self.draft.dispatch_count() + self.target.dispatch_count()
    }

    /// Build the fused-dispatch context when both models' bundles export
    /// batched entry points; `None` (per-lane fallback) otherwise.
    pub fn batched_ctx(&self) -> Result<Option<BatchedCtx>> {
        if self.draft.batch_size().is_none() || self.target.batch_size().is_none() {
            return Ok(None);
        }
        Ok(Some(BatchedCtx { draft: self.draft.new_arena()?, target: self.target.new_arena()? }))
    }

    /// Adopt an owned session into the fused arenas: pack its prefilled
    /// draft/target states over one recycled lane each (two dispatches).
    /// Returns `false` — the session stays owned and is served per-lane —
    /// when either arena is full. On `Err` the session is unusable (its
    /// state may be half-packed) and must be evicted by the caller.
    pub fn adopt(&self, ctx: &mut BatchedCtx, s: &mut SpecSession) -> Result<bool> {
        if s.lane_mode() {
            return Ok(true);
        }
        if ctx.available() == 0 {
            return Ok(false);
        }
        // `available()` said both arenas have room, but if the ledgers ever
        // disagree (asymmetric release bug) degrade to per-lane serving
        // instead of panicking the scheduler mid-batch.
        let Some(dl) = ctx.draft.ledger.alloc() else { return Ok(false) };
        let Some(tl) = ctx.target.ledger.alloc() else {
            let _ = ctx.draft.ledger.free(dl);
            return Ok(false);
        };
        let packed = (|| -> Result<()> {
            let st = s.d_cache.take_state()?;
            let st = self.draft.pack_lane(&mut ctx.draft, dl, st)?;
            s.d_cache.put_state(st);
            let st = s.t_cache.take_state()?;
            let st = self.target.pack_lane(&mut ctx.target, tl, st)?;
            s.t_cache.put_state(st);
            Ok(())
        })();
        if let Err(e) = packed {
            let _ = ctx.draft.ledger.free(dl);
            let _ = ctx.target.ledger.free(tl);
            return Err(e);
        }
        Ok(true)
    }

    /// Release any arena lanes a session holds back to the free lists
    /// (called on every scheduler exit path — finish, eviction, failure).
    /// A no-op on owned sessions; tolerant of half-adopted sessions.
    pub fn release(&self, ctx: &mut BatchedCtx, s: &mut SpecSession) {
        if let Some(SeqState::Lane(l)) = &s.d_cache.state {
            let l = *l;
            s.d_cache.state = None;
            let _ = ctx.draft.ledger.free(l);
        }
        if let Some(SeqState::Lane(l)) = &s.t_cache.state {
            let l = *l;
            s.t_cache.state = None;
            let _ = ctx.target.ledger.free(l);
        }
    }

    /// A prompt the admission path can serve: non-empty and within both
    /// models' context windows — the same bounds `prefill_prompt` enforces
    /// call-by-call, checked up front so a bad prompt is a per-request
    /// admission failure, never a wave-fatal one.
    pub fn validate_prompt(&self, prompt: &[u32]) -> Result<()> {
        if prompt.is_empty() {
            return Err(Error::msg("empty prompt"));
        }
        let cap = self.target.max_seq().min(self.draft.max_seq());
        if prompt.len() > cap {
            return Err(Error::KvCache(format!(
                "prompt of {} tokens exceeds the context window ({cap})",
                prompt.len()
            )));
        }
        Ok(())
    }

    /// Whether admission waves can run at all: both models must share
    /// one prefill block (always true for manifests exporting global
    /// `entry_points`, but checked so an exotic bundle degrades to the
    /// per-sequence admission path instead of failing every wave).
    /// Drivers gate wave admission on this once, up front.
    pub fn wave_capable(&self) -> bool {
        self.target.arch.block(Entry::Prefill) == self.draft.arch.block(Entry::Prefill)
    }

    /// Open a batched admission wave over `prompts`: validate every
    /// prompt, then allocate one draft + one target arena lane per
    /// prompt. Fails (allocating nothing) when the wave exceeds free
    /// arena capacity or any prompt is invalid — the caller decides
    /// which requests to retry per-lane or reject.
    pub fn begin_wave(&self, ctx: &mut BatchedCtx, prompts: Vec<Vec<u32>>) -> Result<PrefillWave> {
        if prompts.is_empty() {
            return Err(Error::msg("empty admission wave"));
        }
        if !self.wave_capable() {
            return Err(Error::msg("draft/target prefill blocks differ: cannot lockstep a wave"));
        }
        let block = self.target.arch.block(Entry::Prefill);
        if prompts.len() > ctx.available() {
            return Err(Error::msg(format!(
                "wave of {} prompts exceeds free arena capacity {}",
                prompts.len(),
                ctx.available()
            )));
        }
        for p in &prompts {
            self.validate_prompt(p)?;
        }
        let max_len = prompts.iter().map(Vec::len).fold(0, usize::max);
        // The capacity check above makes allocation failure unreachable in
        // a consistent ledger; if it happens anyway, roll back every lane
        // this wave took so "fails allocating nothing" still holds.
        let mut entries: Vec<WaveEntry> = Vec::with_capacity(prompts.len());
        for prompt in prompts {
            match (ctx.draft.ledger.alloc(), ctx.target.ledger.alloc()) {
                (Some(d_lane), Some(t_lane)) => {
                    entries.push(WaveEntry { prompt, d_lane, t_lane })
                }
                (d, t) => {
                    if let Some(l) = d {
                        let _ = ctx.draft.ledger.free(l);
                    }
                    if let Some(l) = t {
                        let _ = ctx.target.ledger.free(l);
                    }
                    for e in &entries {
                        let _ = ctx.draft.ledger.free(e.d_lane);
                        let _ = ctx.target.ledger.free(e.t_lane);
                    }
                    return Err(Error::Scheduler(
                        "arena lane allocation failed mid-wave after the capacity check"
                            .into(),
                    ));
                }
            }
        }
        Ok(PrefillWave { entries, pos: 0, max_len, block })
    }

    /// Advance a wave by whole chunks until `budget` prompt tokens have
    /// been prefilled (or the wave drains). Each chunk is ONE fused
    /// batched-prefill dispatch per model over every lane whose prompt
    /// reaches it — ragged lengths just shrink later dispatches. At least
    /// one chunk runs per call (progress guarantee), so a budget smaller
    /// than one chunk degrades to chunk-at-a-time interleaving. Returns
    /// the prompt tokens processed. On `Err` the wave is dead and must be
    /// released with [`SpecDecoder::abort_wave`].
    pub fn wave_step(
        &self,
        ctx: &mut BatchedCtx,
        wave: &mut PrefillWave,
        budget: usize,
    ) -> Result<usize> {
        let block = wave.block;
        let mut spent = 0usize;
        while !wave.done() && (spent == 0 || spent < budget) {
            let start = wave.pos;
            let chunk_tokens = {
                let active: Vec<(usize, usize, &[u32])> = wave
                    .entries
                    .iter()
                    .filter(|e| e.prompt.len() > start)
                    .map(|e| {
                        let chunk = &e.prompt[start..(start + block).min(e.prompt.len())];
                        (e.t_lane, e.d_lane, chunk)
                    })
                    .collect();
                let t_calls: Vec<LaneCall<'_>> = active
                    .iter()
                    .map(|&(t, _, tokens)| LaneCall { lane: t, tokens, pos: start })
                    .collect();
                let d_calls: Vec<LaneCall<'_>> = active
                    .iter()
                    .map(|&(_, d, tokens)| LaneCall { lane: d, tokens, pos: start })
                    .collect();
                let n: usize = active.iter().map(|&(_, _, t)| t.len()).sum();
                self.target.run_lanes(Entry::Prefill, &mut ctx.target, &t_calls)?;
                self.draft.run_lanes(Entry::Prefill, &mut ctx.draft, &d_calls)?;
                n
            };
            wave.pos = start + block;
            spent += chunk_tokens;
        }
        Ok(spent)
    }

    /// Build one drained wave entry's session: caches advanced to the
    /// prompt length over the lane states, last-row logits read from the
    /// arena scratch (preserved through any later masked dispatches —
    /// see [`StateArena::lane_logits`]).
    fn wave_session(&self, ctx: &BatchedCtx, e: &WaveEntry, block: usize) -> Result<SpecSession> {
        let last_row = (e.prompt.len() - 1) % block;
        let t_logits = ctx.target.lane_row(e.t_lane, last_row, self.target.vocab_size()).to_vec();
        let d_logits = ctx.draft.lane_row(e.d_lane, last_row, self.draft.vocab_size()).to_vec();
        // Per-sequence call accounting mirrors the owned path (what one
        // sequence's prefill would have cost); the fused saving is
        // visible in the dispatch counters, not per-session stats.
        let chunks = e.prompt.len().div_ceil(block);
        let stats =
            SpecStats { target_calls: chunks, draft_calls: chunks, ..SpecStats::default() };
        let mut t_cache = SeqCache::new(SeqState::Lane(e.t_lane), self.target.max_seq());
        t_cache.advance(e.prompt.len())?;
        let mut d_cache = SeqCache::new(SeqState::Lane(e.d_lane), self.draft.max_seq());
        d_cache.advance(e.prompt.len())?;
        Ok(SpecSession {
            seq: e.prompt.clone(),
            prompt_len: e.prompt.len(),
            d_cache,
            t_cache,
            t_last_logits: t_logits,
            d_last_logits: d_logits,
            d_logits_buf: Vec::new(),
            t_logits_buf: Vec::new(),
            stats,
            finished: false,
            capture: None,
            trace_id: 0,
        })
    }

    /// Consume a drained wave into ready [`SpecSession`]s (lane-mode, in
    /// prompt order) — the fused equivalent of [`SpecDecoder::start`] +
    /// [`SpecDecoder::adopt`], minus the owned-state allocation, the
    /// host round-trip and the pack dispatches. On `Err` (unreachable
    /// after `begin_wave` validation, kept defensive) every wave lane has
    /// been released — nothing leaks.
    pub fn finish_wave(
        &self,
        ctx: &mut BatchedCtx,
        wave: PrefillWave,
    ) -> Result<Vec<SpecSession>> {
        debug_assert!(wave.done(), "finish_wave before the wave drained");
        let built: Result<Vec<SpecSession>> =
            wave.entries.iter().map(|e| self.wave_session(ctx, e, wave.block)).collect();
        match built {
            Ok(sessions) => Ok(sessions),
            Err(e) => {
                // Built sessions hold lane indices only; free each lane
                // exactly once via the wave.
                self.abort_wave(ctx, wave);
                Err(e)
            }
        }
    }

    /// Release every lane a wave holds back to the arena free lists
    /// (wave-fatal dispatch error, or driver shutdown mid-wave).
    pub fn abort_wave(&self, ctx: &mut BatchedCtx, wave: PrefillWave) {
        for e in &wave.entries {
            let _ = ctx.draft.ledger.free(e.d_lane);
            let _ = ctx.target.ledger.free(e.t_lane);
        }
    }

    /// One-shot batched admission: open a wave over `prompts`, drain it
    /// with no interleaving budget, and return the sessions. On `Err`
    /// every wave lane has been released.
    pub fn admit_wave(
        &self,
        ctx: &mut BatchedCtx,
        prompts: Vec<Vec<u32>>,
    ) -> Result<Vec<SpecSession>> {
        let mut wave = self.begin_wave(ctx, prompts)?;
        if let Err(e) = self.wave_step(ctx, &mut wave, usize::MAX) {
            self.abort_wave(ctx, wave);
            return Err(e);
        }
        self.finish_wave(ctx, wave)
    }

    /// Feed the draft up to one verify-block of tokens it hasn't
    /// processed (at most one model call; zero right after prefill, when
    /// the stored prefill row is the basis) and report whether it
    /// reached the sequence tip. In normal operation the draft is at
    /// most 1-2 tokens behind and one chunk always reaches the tip;
    /// after a degraded (target-only) stretch the backlog can exceed the
    /// verify block, and the caller keeps the block at γ = 0 until
    /// catch-up completes so per-block dispatch cost stays bounded.
    fn sync_draft_chunk(&self, s: &mut SpecSession) -> Result<bool> {
        let l = s.seq.len();
        let d_len = s.d_cache.len();
        if d_len == l {
            return Ok(true);
        }
        let vb = self.draft.arch.block(Entry::Verify);
        let end = l.min(d_len + vb);
        let pending = &s.seq[d_len..end];
        let entry = if pending.len() == 1 { Entry::Decode } else { Entry::Verify };
        let state = s.d_cache.take_state()?;
        let mut buf = std::mem::take(&mut s.d_logits_buf);
        let state = self.draft.run_into(entry, state, pending, d_len, &mut buf)?;
        s.d_cache.put_state(state);
        s.d_cache.advance(pending.len())?;
        s.stats.draft_calls += 1;
        let v = self.draft.vocab_size();
        let off = (pending.len() - 1) * v;
        s.d_last_logits.clear();
        s.d_last_logits.extend_from_slice(&buf[off..off + v]);
        s.d_logits_buf = buf;
        Ok(end == l)
    }

    /// Rebuild a session's draft cache after its device state was lost
    /// to a failed dispatch (per-lane `run_into` consumes the state):
    /// re-prefill the whole sequence into a fresh state. Only reached
    /// with a draft breaker attached — without one the original failure
    /// already evicted the session.
    fn rebuild_draft_state(&self, s: &mut SpecSession) -> Result<()> {
        let (state, logits) = self.draft.prefill_prompt(&s.seq)?;
        let mut d_cache = SeqCache::new(state, self.draft.max_seq());
        d_cache.advance(s.seq.len())?;
        s.d_cache = d_cache;
        s.d_last_logits = logits;
        s.stats.draft_calls += s.seq.len().div_ceil(self.draft.arch.block(Entry::Prefill));
        Ok(())
    }

    /// This session's per-block draft length right now (0 = at capacity).
    fn effective_gamma(&self, s: &SpecSession) -> usize {
        let l = s.seq.len();
        let np = l - s.t_cache.len();
        shrunken_gamma(
            self.gamma,
            l,
            np,
            self.target.max_seq(),
            self.draft.max_seq(),
            self.target.arch.block(Entry::Verify),
        )
    }

    /// Phase 1 — draft sync. Picks the per-block draft length (shrunk near
    /// the context cap) and feeds the draft everything it hasn't processed.
    /// Returns `None` — and marks the session finished — when not even a
    /// γ_eff = 1 block fits (or the session already finished). With a
    /// draft circuit breaker attached, draft unavailability degrades the
    /// block to γ = 0 (target-only) instead of failing the session.
    pub fn begin_block(&self, s: &mut SpecSession) -> Result<Option<BlockState>> {
        if s.finished {
            return Ok(None);
        }
        let gamma = self.effective_gamma(s);
        if gamma == 0 {
            s.finished = true;
            return Ok(None);
        }
        let breaker = self.draft.breaker();
        if let Some(br) = breaker {
            if !br.allow() {
                return Ok(Some(BlockState::degraded()));
            }
            if s.d_cache.state.is_none() && self.rebuild_draft_state(s).is_err() {
                // Re-prefill dispatch failures were recorded by the
                // retry wrapper; un-stick a consumed probe for
                // non-dispatch errors, then serve target-only.
                if br.state() == BreakerState::HalfOpen {
                    br.record_failure();
                }
                return Ok(Some(BlockState::degraded()));
            }
        }
        match self.sync_draft_chunk(s) {
            Ok(true) => {
                // A granted half-open probe that needed no dispatch
                // (draft already at the tip) resolves vacuously — the
                // next real draft call re-tests the circuit.
                if let Some(br) = breaker {
                    if br.state() == BreakerState::HalfOpen {
                        br.record_success();
                    }
                }
                Ok(Some(BlockState {
                    gamma,
                    basis: s.d_last_logits.clone(),
                    drafted: Vec::with_capacity(gamma),
                    draft_probs: Vec::with_capacity(gamma),
                }))
            }
            // Catch-up in progress: the draft advanced one verify-block
            // toward the tip; this block runs target-only.
            Ok(false) => Ok(Some(BlockState::degraded())),
            Err(e) => {
                let Some(br) = breaker else { return Err(e) };
                // Dispatch failures were recorded by the retry wrapper;
                // un-stick a consumed probe for non-dispatch errors.
                if br.state() == BreakerState::HalfOpen {
                    br.record_failure();
                }
                Ok(Some(BlockState::degraded()))
            }
        }
    }

    /// Phase 2 — one proposal round: sample draft token j from the current
    /// basis, then run one draft decode for the next basis — except after
    /// the last round (if the last token survives verification, the next
    /// block's sync ingests it; that keeps draft calls per block at γ_eff).
    pub fn propose_round(
        &self,
        s: &mut SpecSession,
        b: &mut BlockState,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<()> {
        debug_assert!(b.drafted.len() < b.gamma, "proposal round past gamma");
        let v = self.target.vocab_size();
        let p = logits_to_probs(&b.basis, cfg);
        let t = sample_token(&p, cfg, rng);
        b.drafted.push(t);
        b.draft_probs.push(p);
        if b.drafted.len() < b.gamma {
            let pos = s.d_cache.len();
            let state = s.d_cache.take_state()?;
            let mut buf = std::mem::take(&mut s.d_logits_buf);
            let state = self.draft.run_into(Entry::Decode, state, &[t], pos, &mut buf)?;
            s.d_cache.put_state(state);
            s.d_cache.advance(1)?;
            s.stats.draft_calls += 1;
            b.basis.clear();
            b.basis.extend_from_slice(&buf[..v]);
            s.d_logits_buf = buf;
        }
        Ok(())
    }

    /// Phases 3 + 4 — one target verify over [pending ++ drafted], then
    /// rejection sampling, cache rollback and EOS handling. Returns the
    /// emitted tokens (1..=γ_eff+1, never empty).
    pub fn commit_block(
        &self,
        s: &mut SpecSession,
        b: BlockState,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<Vec<u32>> {
        debug_assert_eq!(b.drafted.len(), b.gamma, "commit before all proposal rounds");
        let l = s.seq.len();

        // 3. — one target verify over [pending ++ drafted].
        let t_len = s.t_cache.len();
        let np = l - t_len;
        let mut fed: Vec<u32> = s.seq[t_len..l].to_vec();
        fed.extend_from_slice(&b.drafted);
        if fed.is_empty() {
            // γ = 0 degraded block with the target already at the tip
            // (right after a prefill or a lane salvage): nothing to
            // feed — sample straight from the stored last target row.
            let rows = std::mem::take(&mut s.t_logits_buf);
            let out = self.finish_block(s, b, 0, &rows, cfg, rng);
            s.t_logits_buf = rows;
            return out;
        }
        debug_assert!(fed.len() <= self.target.arch.block(Entry::Verify));
        let state = s.t_cache.take_state()?;
        let mut rows = std::mem::take(&mut s.t_logits_buf);
        let state = match self.target.run_into(Entry::Verify, state, &fed, t_len, &mut rows) {
            Ok(st) => st,
            Err(e) => {
                s.t_logits_buf = rows;
                return Err(e);
            }
        };
        s.t_cache.put_state(state);
        if let Err(e) = s.t_cache.advance(fed.len()) {
            s.t_logits_buf = rows;
            return Err(e);
        }
        s.stats.target_calls += 1;
        let out = self.finish_block(s, b, np, &rows, cfg, rng);
        s.t_logits_buf = rows;
        out
    }

    /// Phase 4 — rejection sampling, cache rollback, EOS handling and
    /// capture, given the verify call's raw logits rows (`fed.len() * V`
    /// floats). Shared by the per-lane and fused-batched commit paths; the
    /// caller has already advanced the target cache past the fed tokens.
    fn finish_block(
        &self,
        s: &mut SpecSession,
        b: BlockState,
        np: usize,
        t_rows: &[f32],
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<Vec<u32>> {
        let BlockState { gamma, drafted, draft_probs, .. } = b;
        let l = s.seq.len();
        let v = self.target.vocab_size();
        s.stats.drafted += gamma;
        s.stats.blocks += 1;

        // Assemble q_0..q_gamma.
        let row = |i: usize| -> &[f32] { &t_rows[i * v..(i + 1) * v] };
        let mut target_probs: Vec<Vec<f32>> = Vec::with_capacity(gamma + 1);
        for j in 0..=gamma {
            let probs = if j == 0 && np == 0 {
                logits_to_probs(&s.t_last_logits, cfg)
            } else {
                logits_to_probs(row(np + j - 1), cfg)
            };
            target_probs.push(probs);
        }

        // Rejection sampling + rollback.
        let out = verify_block(&draft_probs, &target_probs, &drafted, rng);
        let k = out.accepted;
        s.stats.accepted += k;

        // Valid processed positions: target saw pending + all gamma drafted,
        // but only the first k drafted survive; the draft processed only the
        // first gamma-1 drafted tokens. During degraded (γ = 0) stretches
        // the draft cache lags the sequence, so its rollback clamps to the
        // positions it actually holds.
        s.t_cache.rollback_to(l + k)?;
        s.d_cache.rollback_to(s.d_cache.len().min(l + k.min(gamma.saturating_sub(1))))?;

        let mut emitted: Vec<u32> = drafted[..k].to_vec();
        emitted.push(out.next_token);
        s.stats.generated += emitted.len();

        // EOS: truncate at the first EOS (inclusive) and finish.
        if let Some(eos_at) = emitted.iter().position(|&t| t == EOS) {
            emitted.truncate(eos_at + 1);
            // Roll validity back to the kept prefix.
            let keep = l + emitted.len();
            s.t_cache.rollback_to(s.t_cache.len().min(keep))?;
            s.d_cache.rollback_to(s.d_cache.len().min(keep))?;
            s.finished = true;
        }
        // Distillation capture: emitted[j] was verified/sampled against
        // q_j, whose raw logits row the verify call already returned
        // (position 0 right after prefill reuses the stored prefill row).
        // Runs after the EOS truncation so rows stay aligned with the kept
        // tokens.
        if let Some(cap) = s.capture.as_mut() {
            let t0 = std::time::Instant::now();
            for j in 0..emitted.len() {
                let raw: &[f32] =
                    if j == 0 && np == 0 { &s.t_last_logits } else { row(np + j - 1) };
                cap.rows.push(topk_of_row(raw, cap.topk));
            }
            cap.seconds += t0.elapsed().as_secs_f64();
        }
        s.seq.extend_from_slice(&emitted);
        if s.trace_id != 0 && crate::trace::enabled() {
            crate::trace::req_block(s.trace_id, k as u64, emitted.len() as u64);
        }
        Ok(emitted)
    }

    /// Phase 1 (fused) — draft-sync sweep over every adopted lane in at
    /// most two dispatches (one batched decode for single-pending lanes,
    /// one batched verify for the rest — the same entry selection as the
    /// per-lane path, so the computed rows match it numerically). Fills
    /// `blocks[i]` for lanes that begin a block, marks at-capacity
    /// sessions finished, and records per-lane failures in `failed[i]`.
    /// `Err` means a shared dispatch failed (all adopted lanes are dead).
    pub fn begin_block_batch(
        &self,
        ctx: &mut BatchedCtx,
        lanes: &mut [Lane<'_>],
        blocks: &mut [Option<BlockState>],
        failed: &mut [Option<Error>],
    ) -> Result<()> {
        let v = self.draft.vocab_size();
        let vb = self.draft.arch.block(Entry::Verify);
        let breaker = self.draft.breaker();
        let draft_ok = breaker.map_or(true, |br| br.allow());
        struct Sync {
            i: usize,
            lane: usize,
            pending: Vec<u32>,
            pos: usize,
        }
        let mut syncs: Vec<Sync> = Vec::new();
        for (i, lane) in lanes.iter_mut().enumerate() {
            let s = &mut *lane.session;
            if !s.lane_mode() || failed[i].is_some() || s.finished {
                continue;
            }
            let gamma = self.effective_gamma(s);
            if gamma == 0 {
                s.finished = true;
                continue;
            }
            // Draft circuit open: every lane runs a target-only block.
            if !draft_ok {
                blocks[i] = Some(BlockState::degraded());
                continue;
            }
            let d_len = s.d_cache.len();
            // Catch-up is capped at one verify-block per iteration; a
            // lane still behind after its chunk runs target-only.
            let end = s.seq.len().min(d_len + vb);
            blocks[i] = Some(if end < s.seq.len() {
                BlockState::degraded()
            } else {
                BlockState {
                    gamma,
                    basis: Vec::new(),
                    drafted: Vec::with_capacity(gamma),
                    draft_probs: Vec::with_capacity(gamma),
                }
            });
            if d_len < end {
                syncs.push(Sync {
                    i,
                    // lint: allow(no-panic, lane_mode() at the loop top guarantees a draft lane)
                    lane: s.d_lane().expect("lane-mode session has a draft lane"),
                    pending: s.seq[d_len..end].to_vec(),
                    pos: d_len,
                });
            }
        }
        // Same entry selection as the per-lane sync: decode for one
        // pending token, verify otherwise — one fused dispatch per entry
        // in use. `draft_down` absorbs a failed draft dispatch when a
        // breaker is attached: the failing group and every group not yet
        // run degrade to target-only blocks (their arena states are
        // untouched — `run_lanes` leaves lane state intact on error — so
        // catch-up resumes once the circuit closes).
        let mut draft_down = false;
        for want_decode in [true, false] {
            let in_group = |c: &&Sync| (c.pending.len() == 1) == want_decode;
            if !draft_down {
                let calls: Vec<LaneCall<'_>> = syncs
                    .iter()
                    .filter(in_group)
                    .map(|c| LaneCall { lane: c.lane, tokens: &c.pending, pos: c.pos })
                    .collect();
                if calls.is_empty() {
                    continue;
                }
                let entry = if want_decode { Entry::Decode } else { Entry::Verify };
                match self.draft.run_lanes(entry, &mut ctx.draft, &calls) {
                    Ok(()) => {}
                    Err(_) if breaker.is_some() => draft_down = true,
                    Err(e) => return Err(e),
                }
                drop(calls);
            }
            for c in syncs.iter().filter(in_group) {
                if draft_down {
                    blocks[c.i] = Some(BlockState::degraded());
                    continue;
                }
                let s = &mut *lanes[c.i].session;
                let rows = ctx.draft.lane_logits(c.lane, c.pending.len(), v);
                let off = (c.pending.len() - 1) * v;
                s.d_last_logits.clear();
                s.d_last_logits.extend_from_slice(&rows[off..off + v]);
                s.stats.draft_calls += 1;
                if let Err(e) = s.d_cache.advance(c.pending.len()) {
                    failed[c.i] = Some(e);
                    blocks[c.i] = None;
                }
            }
        }
        // A granted half-open probe with nothing to sync resolves
        // vacuously — the next real draft call re-tests the circuit.
        if let Some(br) = breaker {
            if draft_ok && syncs.is_empty() && br.state() == BreakerState::HalfOpen {
                br.record_success();
            }
        }
        // Proposal-0 basis: the (now fresh) last draft row of every lane
        // that begins a block this step.
        for (i, lane) in lanes.iter_mut().enumerate() {
            if lane.session.lane_mode() && failed[i].is_none() {
                if let Some(b) = blocks[i].as_mut() {
                    b.basis.clear();
                    b.basis.extend_from_slice(&lane.session.d_last_logits);
                }
            }
        }
        Ok(())
    }

    /// Phase 2 (fused) — one proposal round across every adopted drafting
    /// lane: sample token j per lane from its basis (host RNG, per-lane
    /// order identical to the single-lane path), then ONE batched decode
    /// dispatch for every lane that still needs a next basis. Lanes whose
    /// shrunken γ is exhausted sit the round out.
    pub fn propose_round_batch(
        &self,
        ctx: &mut BatchedCtx,
        lanes: &mut [Lane<'_>],
        blocks: &mut [Option<BlockState>],
        failed: &mut [Option<Error>],
    ) -> Result<()> {
        let v = self.target.vocab_size();
        struct Dec {
            i: usize,
            lane: usize,
            tok: u32,
            pos: usize,
        }
        let mut decs: Vec<Dec> = Vec::new();
        for (i, lane) in lanes.iter_mut().enumerate() {
            if !lane.session.lane_mode() || failed[i].is_some() {
                continue;
            }
            let Some(b) = blocks[i].as_mut() else { continue };
            if b.proposed() >= b.gamma() {
                continue;
            }
            let p = logits_to_probs(&b.basis, &lane.sampling);
            let t = sample_token(&p, &lane.sampling, lane.rng);
            b.drafted.push(t);
            b.draft_probs.push(p);
            if b.drafted.len() < b.gamma {
                decs.push(Dec {
                    i,
                    // lint: allow(no-panic, lane_mode() at the loop top guarantees a draft lane)
                    lane: lane.session.d_lane().expect("lane-mode session has a draft lane"),
                    tok: t,
                    pos: lane.session.d_cache.len(),
                });
            }
        }
        if decs.is_empty() {
            return Ok(());
        }
        let calls: Vec<LaneCall<'_>> = decs
            .iter()
            .map(|c| LaneCall { lane: c.lane, tokens: std::slice::from_ref(&c.tok), pos: c.pos })
            .collect();
        if let Err(e) = self.draft.run_lanes(Entry::Decode, &mut ctx.draft, &calls) {
            if self.draft.breaker().is_none() {
                return Err(e);
            }
            drop(calls);
            // Draft died mid-block (failure recorded by the retry
            // wrapper): truncate every drafting lane's block to what it
            // proposed so far — commit verifies the shorter block, and
            // the breaker decides whether the next block runs degraded.
            // Draft caches were not advanced (`run_lanes` leaves arena
            // state intact on error), so they stay consistent.
            for c in &decs {
                if let Some(b) = blocks[c.i].as_mut() {
                    b.gamma = b.drafted.len();
                }
            }
            return Ok(());
        }
        drop(calls);
        for c in &decs {
            let s = &mut *lanes[c.i].session;
            let rows = ctx.draft.lane_logits(c.lane, 1, v);
            // lint: allow(no-panic, decs only holds lanes whose block was set this phase)
            let b = blocks[c.i].as_mut().expect("drafting lane has a block");
            b.basis.clear();
            b.basis.extend_from_slice(&rows[..v]);
            s.stats.draft_calls += 1;
            if let Err(e) = s.d_cache.advance(1) {
                failed[c.i] = Some(e);
                blocks[c.i] = None;
            }
        }
        Ok(())
    }

    /// Phase 3 (fused) — ONE batched target-verify dispatch over every
    /// adopted lane with a completed block, then per-lane rejection
    /// sampling / rollback / EOS ([`finish_block`](Self::commit_block)'s
    /// shared tail). Emitted tokens land in `emitted[i]`.
    pub fn commit_block_batch(
        &self,
        ctx: &mut BatchedCtx,
        lanes: &mut [Lane<'_>],
        blocks: &mut [Option<BlockState>],
        failed: &mut [Option<Error>],
        emitted: &mut [Option<Vec<u32>>],
    ) -> Result<()> {
        let v = self.target.vocab_size();
        struct Ver {
            i: usize,
            lane: usize,
            fed: Vec<u32>,
            pos: usize,
            np: usize,
        }
        let mut vers: Vec<Ver> = Vec::new();
        let mut empties: Vec<usize> = Vec::new();
        for (i, lane) in lanes.iter_mut().enumerate() {
            if !lane.session.lane_mode() || failed[i].is_some() {
                continue;
            }
            let Some(b) = blocks[i].as_ref() else { continue };
            debug_assert_eq!(b.drafted.len(), b.gamma, "commit before all proposal rounds");
            let s = &*lane.session;
            let t_len = s.t_cache.len();
            let mut fed: Vec<u32> = s.seq[t_len..].to_vec();
            fed.extend_from_slice(&b.drafted);
            if fed.is_empty() {
                // γ = 0 degraded block with the target already at the
                // tip (right after a prefill or a lane salvage): nothing
                // to feed — finish from the stored last target row.
                empties.push(i);
                continue;
            }
            debug_assert!(fed.len() <= self.target.arch.block(Entry::Verify));
            vers.push(Ver {
                i,
                // lint: allow(no-panic, lane_mode() at the loop top guarantees a target lane)
                lane: s.t_lane().expect("lane-mode session has a target lane"),
                fed,
                pos: t_len,
                np: s.seq.len() - t_len,
            });
        }
        for &i in &empties {
            let Lane { session, sampling, rng } = &mut lanes[i];
            // lint: allow(no-panic, empties only holds lanes whose block was set this phase)
            let b = blocks[i].take().expect("empty-fed lane has a block");
            match self.finish_block(session, b, 0, &[], sampling, rng) {
                Ok(tokens) => emitted[i] = Some(tokens),
                Err(e) => failed[i] = Some(e),
            }
        }
        if vers.is_empty() {
            return Ok(());
        }
        let calls: Vec<LaneCall<'_>> = vers
            .iter()
            .map(|c| LaneCall { lane: c.lane, tokens: &c.fed, pos: c.pos })
            .collect();
        self.target.run_lanes(Entry::Verify, &mut ctx.target, &calls)?;
        drop(calls);
        for c in &vers {
            let Lane { session, sampling, rng } = &mut lanes[c.i];
            // lint: allow(no-panic, vers only holds lanes whose block survived the propose phase)
            let b = blocks[c.i].take().expect("verified lane has a block");
            let rows = ctx.target.lane_logits(c.lane, c.fed.len(), v);
            let done = match session.t_cache.advance(c.fed.len()) {
                Ok(()) => {
                    session.stats.target_calls += 1;
                    self.finish_block(session, b, c.np, rows, sampling, rng)
                }
                Err(e) => Err(e),
            };
            match done {
                Ok(tokens) => emitted[c.i] = Some(tokens),
                Err(e) => failed[c.i] = Some(e),
            }
        }
        Ok(())
    }

    /// Run one speculation block; returns the tokens emitted (empty only
    /// when the session is finished or at capacity). Single-sequence
    /// composition of the phase methods — the batch scheduler runs the
    /// same phases in lockstep across sequences, consuming each lane's
    /// RNG in the same order, so batched and direct output match.
    pub fn step(
        &self,
        s: &mut SpecSession,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<Vec<u32>> {
        let Some(mut b) = self.begin_block(s)? else {
            return Ok(Vec::new());
        };
        for _ in 0..b.gamma {
            if let Err(e) = self.propose_round(s, &mut b, cfg, rng) {
                if self.draft.breaker().is_none() {
                    return Err(e);
                }
                // Draft died mid-block (failure recorded by the retry
                // wrapper): verify only what was proposed so far; the
                // breaker decides whether the next block runs degraded.
                b.gamma = b.drafted.len();
                break;
            }
        }
        self.commit_block(s, b, cfg, rng)
    }

    /// Convenience driver: generate until EOS / max_new / capacity.
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<(Vec<u32>, SpecStats)> {
        let mut session = self.start(prompt)?;
        while !session.finished && session.generated().len() < max_new {
            let emitted = self.step(&mut session, cfg, rng)?;
            if emitted.is_empty() {
                break;
            }
        }
        let mut out = session.generated().to_vec();
        out.truncate(max_new);
        // The final block can overshoot max_new; the reported counters must
        // describe the *delivered* tokens or block efficiency inflates.
        session.stats.clip_to_delivered(out.len());
        Ok((out, session.stats))
    }
}

#[cfg(test)]
mod tests {
    // The engine needs compiled artifacts; its integration tests live in
    // rust/tests/spec_equivalence.rs. Here we pin the pure bookkeeping.
    use super::{shrunken_gamma, LogitCapture};
    use crate::metrics::SpecStats;
    use crate::runtime::TopkRow;

    #[test]
    fn stats_default_zero() {
        let s = SpecStats::default();
        assert_eq!(s.block_efficiency(), 0.0);
        assert_eq!(s.acceptance_rate(), 0.0);
    }

    #[test]
    fn shrunken_gamma_full_when_room() {
        // Far from every cap: the configured gamma is used unchanged.
        assert_eq!(shrunken_gamma(3, 10, 1, 256, 256, 8), 3);
        assert_eq!(shrunken_gamma(5, 0, 0, 256, 256, 8), 5);
    }

    #[test]
    fn shrunken_gamma_target_cap_binds() {
        // Target can only advance max_seq - l more positions.
        assert_eq!(shrunken_gamma(5, 254, 1, 256, 512, 8), 2);
        assert_eq!(shrunken_gamma(5, 255, 1, 256, 512, 8), 1);
        assert_eq!(shrunken_gamma(5, 256, 1, 256, 512, 8), 0, "at capacity");
    }

    #[test]
    fn shrunken_gamma_draft_cap_binds() {
        // Draft advances to l + gamma - 1, so it allows one extra position.
        assert_eq!(shrunken_gamma(5, 254, 1, 512, 256, 8), 3);
        assert_eq!(shrunken_gamma(5, 256, 1, 512, 256, 8), 1, "sync-only block");
        assert_eq!(shrunken_gamma(5, 257, 1, 512, 256, 8), 0);
    }

    #[test]
    fn shrunken_gamma_verify_block_binds() {
        // The verify call re-feeds np pending tokens alongside the draft.
        assert_eq!(shrunken_gamma(5, 10, 4, 256, 256, 8), 4);
        assert_eq!(shrunken_gamma(5, 10, 8, 256, 256, 8), 0);
    }

    #[test]
    fn capture_clip_truncates_rows_only() {
        let mut cap = LogitCapture { topk: 2, rows: Vec::new(), seconds: 0.25 };
        for i in 0..5u32 {
            cap.rows.push(TopkRow { ids: vec![i, i + 1], logits: vec![1.0, 0.5] });
        }
        cap.clip_to(3);
        assert_eq!(cap.rows.len(), 3);
        assert_eq!(cap.rows[2].ids, vec![2, 3]);
        // Never grows, and the overhead accounting is untouched.
        cap.clip_to(10);
        assert_eq!(cap.rows.len(), 3);
        assert!((cap.seconds - 0.25).abs() < 1e-12);
    }

    #[test]
    fn prefill_wave_cursor_arithmetic() {
        use super::{PrefillWave, WaveEntry};
        // Ragged wave: single-token, multi-chunk and exact-boundary
        // prompts share one lockstep cursor.
        let mut w = PrefillWave {
            entries: vec![
                WaveEntry { prompt: vec![1], d_lane: 0, t_lane: 0 },
                WaveEntry { prompt: (0..70).collect(), d_lane: 1, t_lane: 1 },
                WaveEntry { prompt: (0..32).collect(), d_lane: 2, t_lane: 2 },
            ],
            pos: 0,
            max_len: 70,
            block: 32,
        };
        assert_eq!(w.lanes(), 3);
        assert!(!w.done());
        assert_eq!(w.total_tokens(), 103);
        assert_eq!(w.remaining_tokens(), 103);
        assert_eq!(w.remaining_chunks(), 3, "ceil(70/32): bound is the LONGEST prompt");
        w.pos = 32;
        assert_eq!(w.remaining_tokens(), 38, "short lanes dropped out");
        assert_eq!(w.remaining_chunks(), 2);
        w.pos = 64;
        assert_eq!(w.remaining_tokens(), 6);
        assert_eq!(w.remaining_chunks(), 1);
        w.pos = 96; // cursor overshoots the longest prompt by padding
        assert!(w.done());
        assert_eq!(w.remaining_tokens(), 0);
        assert_eq!(w.remaining_chunks(), 0);
    }

    #[test]
    fn shrunken_gamma_never_exceeds_configured() {
        for l in 0..300 {
            let g = shrunken_gamma(3, l, 1, 256, 256, 8);
            assert!(g <= 3);
            // Monotone non-increasing in l once caps start binding.
            assert!(g >= shrunken_gamma(3, l + 1, 1, 256, 256, 8));
        }
    }
}

//! The speculative decoding engine: draft-γ-then-verify with KV rollback.
//!
//! Per block (one target run), following Leviathan et al. as deployed in
//! the paper's evaluation:
//!
//! 1. **draft sync** — feed the tokens the draft hasn't processed yet
//!    (1-2 tokens after the first block) in ONE draft call; its last
//!    logits row is the basis for proposal 0.
//! 2. **draft proposals** — sample γ tokens autoregressively; only γ-1
//!    decode calls are needed because proposal j's basis is the decode of
//!    t_{j-1} and the last proposed token is *not* pre-processed (if it
//!    survives verification the next block's sync ingests it). Total draft
//!    calls per block = γ, exactly the paper's c·γ cost model.
//! 3. **target verify** — one call processing [pending ++ drafted] (≤ γ+1
//!    ≤ the exported verify block of 8) yielding the γ+1 target
//!    distributions q_0..q_γ.
//! 4. **rejection sampling** — [`sampling::verify_block`]; on rejection the
//!    caches *roll back by length only* (the position-masked attention
//!    contract makes stale rows unreachable).
//!
//! The engine is single-sequence; the [`crate::coordinator`] interleaves
//! many sessions over it (iteration-level scheduling).

use crate::config::SamplingConfig;
use crate::error::{Error, Result};
use crate::kvcache::SeqCache;
use crate::metrics::SpecStats;
use crate::rng::Pcg64;
use crate::runtime::{Entry, Model, SeqState};
use crate::sampling::{logits_to_probs, sample_token, verify_block};
use crate::tokenizer::EOS;

/// Engine configuration + model handles.
pub struct SpecDecoder<'a> {
    pub draft: &'a Model,
    pub target: &'a Model,
    pub gamma: usize,
}

/// One in-flight sequence.
pub struct SpecSession {
    /// prompt ++ generated tokens (ground truth sequence).
    pub seq: Vec<u32>,
    pub prompt_len: usize,
    d_cache: SeqCache<SeqState>,
    t_cache: SeqCache<SeqState>,
    /// Last target logits row (prediction for position seq.len()) — only
    /// consulted when the target has no pending tokens (right after prefill).
    t_last_logits: Vec<f32>,
    /// Last draft logits row — consulted when the draft has no pending
    /// tokens (right after prefill, before the first speculation block).
    d_last_logits: Vec<f32>,
    pub stats: SpecStats,
    pub finished: bool,
}

impl SpecSession {
    pub fn generated(&self) -> &[u32] {
        &self.seq[self.prompt_len..]
    }
}

impl<'a> SpecDecoder<'a> {
    pub fn new(draft: &'a Model, target: &'a Model, gamma: usize) -> Result<Self> {
        let verify_block_size = target.arch.block(Entry::Verify);
        if gamma + 1 > verify_block_size {
            return Err(Error::msg(format!(
                "gamma {gamma} needs verify block >= {} (exported: {verify_block_size})",
                gamma + 1
            )));
        }
        if gamma == 0 {
            return Err(Error::msg("gamma must be >= 1"));
        }
        Ok(SpecDecoder { draft, target, gamma })
    }

    /// Prefill both models on the prompt.
    pub fn start(&self, prompt: &[u32]) -> Result<SpecSession> {
        if prompt.is_empty() {
            return Err(Error::msg("empty prompt"));
        }
        let mut stats = SpecStats::default();
        let (t_state, t_logits) = self.target.prefill_prompt(prompt)?;
        let (d_state, d_logits) = self.draft.prefill_prompt(prompt)?;
        let pf_block = self.target.arch.block(Entry::Prefill);
        stats.target_calls += prompt.len().div_ceil(pf_block);
        stats.draft_calls += prompt.len().div_ceil(self.draft.arch.block(Entry::Prefill));

        let mut t_cache = SeqCache::new(t_state, self.target.max_seq());
        t_cache.advance(prompt.len())?;
        let mut d_cache = SeqCache::new(d_state, self.draft.max_seq());
        d_cache.advance(prompt.len())?;

        Ok(SpecSession {
            seq: prompt.to_vec(),
            prompt_len: prompt.len(),
            d_cache,
            t_cache,
            t_last_logits: t_logits,
            d_last_logits: d_logits,
            stats,
            finished: false,
        })
    }

    /// Feed the draft everything it hasn't processed and return its last
    /// logits row (the proposal-0 basis). At most one model call; zero
    /// right after prefill, when the stored prefill row is the basis.
    fn sync_draft(&self, s: &mut SpecSession) -> Result<Vec<f32>> {
        let l = s.seq.len();
        let d_len = s.d_cache.len();
        if d_len == l {
            return Ok(s.d_last_logits.clone());
        }
        let pending = &s.seq[d_len..l];
        let vb = self.draft.arch.block(Entry::Verify);
        debug_assert!(pending.len() <= vb, "draft pending {} > verify block {vb}", pending.len());
        let entry = if pending.len() == 1 { Entry::Decode } else { Entry::Verify };
        let state = s.d_cache.take_state()?;
        let (state, logits) = self.draft.run(entry, state, pending, d_len)?;
        s.d_cache.put_state(state);
        s.d_cache.advance(pending.len())?;
        s.stats.draft_calls += 1;
        let v = self.draft.vocab_size();
        let off = (pending.len() - 1) * v;
        s.d_last_logits = logits[off..off + v].to_vec();
        Ok(s.d_last_logits.clone())
    }

    /// Run one speculation block; returns the tokens emitted (1..=gamma+1).
    pub fn step(
        &self,
        s: &mut SpecSession,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<Vec<u32>> {
        if s.finished {
            return Ok(Vec::new());
        }
        let gamma = self.gamma;
        let l = s.seq.len();
        let v = self.target.vocab_size();

        // Capacity guard: a block can add gamma+1 tokens and the models
        // must be able to process them next round.
        if l + 2 * (gamma + 1) >= self.target.max_seq() {
            s.finished = true;
            return Ok(Vec::new());
        }

        // 1. + 2. — draft sync and proposals (gamma draft calls in total).
        let mut basis = self.sync_draft(s)?;
        let mut drafted: Vec<u32> = Vec::with_capacity(gamma);
        let mut draft_probs: Vec<Vec<f32>> = Vec::with_capacity(gamma);
        for j in 0..gamma {
            let p = logits_to_probs(&basis, cfg);
            let t = sample_token(&p, cfg, rng);
            drafted.push(t);
            draft_probs.push(p);
            if j + 1 < gamma {
                let state = s.d_cache.take_state()?;
                let (state, logits) = self.draft.run(Entry::Decode, state, &[t], s.d_cache.len())?;
                s.d_cache.put_state(state);
                s.d_cache.advance(1)?;
                s.stats.draft_calls += 1;
                basis = logits[..v].to_vec();
            }
        }
        s.stats.drafted += gamma;

        // 3. — one target verify over [pending ++ drafted].
        let t_len = s.t_cache.len();
        let pending_t: Vec<u32> = s.seq[t_len..l].to_vec();
        let mut fed = pending_t.clone();
        fed.extend_from_slice(&drafted);
        debug_assert!(fed.len() <= self.target.arch.block(Entry::Verify));
        let state = s.t_cache.take_state()?;
        let (state, t_logits) = self.target.run(Entry::Verify, state, &fed, t_len)?;
        s.t_cache.put_state(state);
        s.t_cache.advance(fed.len())?;
        s.stats.target_calls += 1;
        s.stats.blocks += 1;

        // Assemble q_0..q_gamma.
        let np = pending_t.len();
        let row = |i: usize| -> &[f32] { &t_logits[i * v..(i + 1) * v] };
        let mut target_probs: Vec<Vec<f32>> = Vec::with_capacity(gamma + 1);
        for j in 0..=gamma {
            let probs = if j == 0 && np == 0 {
                logits_to_probs(&s.t_last_logits, cfg)
            } else {
                logits_to_probs(row(np + j - 1), cfg)
            };
            target_probs.push(probs);
        }

        // 4. — rejection sampling + rollback.
        let out = verify_block(&draft_probs, &target_probs, &drafted, rng);
        let k = out.accepted;
        s.stats.accepted += k;

        // Valid processed positions: target saw pending + all gamma drafted,
        // but only the first k drafted survive; the draft processed only the
        // first gamma-1 drafted tokens.
        s.t_cache.rollback_to(l + k)?;
        s.d_cache.rollback_to(l + k.min(gamma.saturating_sub(1)))?;

        let mut emitted: Vec<u32> = drafted[..k].to_vec();
        emitted.push(out.next_token);
        s.stats.generated += emitted.len();

        // EOS: truncate at the first EOS (inclusive) and finish.
        if let Some(eos_at) = emitted.iter().position(|&t| t == EOS) {
            emitted.truncate(eos_at + 1);
            // Roll validity back to the kept prefix.
            let keep = l + emitted.len();
            s.t_cache.rollback_to(s.t_cache.len().min(keep))?;
            s.d_cache.rollback_to(s.d_cache.len().min(keep))?;
            s.finished = true;
        }
        s.seq.extend_from_slice(&emitted);
        Ok(emitted)
    }

    /// Convenience driver: generate until EOS / max_new / capacity.
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<(Vec<u32>, SpecStats)> {
        let mut session = self.start(prompt)?;
        while !session.finished && session.generated().len() < max_new {
            let emitted = self.step(&mut session, cfg, rng)?;
            if emitted.is_empty() {
                break;
            }
        }
        let mut out = session.generated().to_vec();
        out.truncate(max_new);
        Ok((out, session.stats))
    }
}

#[cfg(test)]
mod tests {
    // The engine needs compiled artifacts; its integration tests live in
    // rust/tests/spec_equivalence.rs. Here we pin the pure bookkeeping.
    use crate::metrics::SpecStats;

    #[test]
    fn stats_default_zero() {
        let s = SpecStats::default();
        assert_eq!(s.block_efficiency(), 0.0);
        assert_eq!(s.acceptance_rate(), 0.0);
    }
}

//! Supervised draft lifecycle: validated hot bundle swaps, guarded
//! adoption with automatic rollback, and scheduler-panic supervision.
//!
//! The paper's premise is that draft quality is a moving target: drafts
//! are cheap to retrain (§4 trains to convergence in hours on one node)
//! and acceptance rate — not draft loss — is the serving objective. This
//! module closes the loop operationally: a freshly distilled bundle can
//! be adopted by a *running* server without dropping a request, and a
//! bundle that looks fine offline but collapses acceptance online is
//! rolled back automatically.
//!
//! ```text
//!   POST /v1/admin/reload-draft
//!        │ (mailbox arm)
//!        ▼
//!   scheduler loop, at a block boundary:
//!        stage:   load candidate into a staging Model on the scheduler
//!                 thread (manifest compat + weights parse + golden
//!                 probes — runtime::stage_draft). Failure → rejected,
//!                 serving untouched.
//!        quiesce: dismantle the serving segment — every resident
//!                 sequence (prompt ++ emitted) becomes a ResumeState.
//!        swap:    supervisor installs the staged model, keeps the old
//!                 one as last-known-good, re-admits every resident via
//!                 the normal admission wave (re-prefill + transplant:
//!                 token-identical emitted prefixes, no duplicate or
//!                 lost deltas, terminal() still fires exactly once).
//!        guard:   for `swap_guard_blocks` blocks the new draft is on
//!                 probation: an acceptance-drift CUSUM fire, an accept
//!                 rate below `swap_accept_floor`, or the draft breaker
//!                 opening rolls back to last-known-good the same way.
//! ```
//!
//! Separately, the supervisor wraps every serving segment in
//! `catch_unwind`: a scheduler panic no longer kills the process — the
//! in-flight requests recorded in the [`Lifecycle`] registry are either
//! re-admitted into a fresh loop (fresh `BatchedCtx`, fresh slot pool)
//! or, for a crash-looping scheduler, stranded with exactly one terminal
//! error each ([`crate::coordinator::strand_terminal`]).
//!
//! Exported metric families (all defined here, documented in
//! docs/METRICS.md): `specd_draft_generation`,
//! `specd_draft_swaps_total{outcome}`, `specd_scheduler_restarts_total`.

use std::collections::BTreeMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::{self, Coordinator, Exit, GuardSpec, Request, Response, ResumeState};
use crate::error::{Error, Result};
use crate::exec::{Receiver, Sender};
use crate::metrics::{prom_counter, prom_gauge, ServeMetrics};
use crate::rng::Pcg64;
use crate::runtime::{CompiledArch, Model, Runtime};
use crate::spec::SpecDecoder;

/// More scheduler panics than this inside [`RESTART_STORM_WINDOW`] is a
/// crash loop, not a transient: the supervisor stops resuscitating,
/// strands the registry and fails the serve call.
pub const RESTART_STORM_CAP: usize = 3;
/// Sliding window for the restart-storm detector.
pub const RESTART_STORM_WINDOW: Duration = Duration::from_secs(60);

/// Serving state surfaced by `/readyz` and the admin status endpoint.
/// Stored as a u64 in [`Lifecycle`] so readers never take a lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum State {
    /// Process boot: models loading, scheduler not yet serving.
    Starting = 0,
    /// Steady state.
    Serving = 1,
    /// A staged swap is dismantling the current segment (brief).
    Quiescing = 2,
    /// Post-swap probation window; rollback triggers are armed.
    Guarding = 3,
    /// The scheduler panicked and the supervisor is rebuilding the loop.
    Restarting = 4,
    /// SIGTERM received: admission closed, residents draining.
    Draining = 5,
}

impl State {
    pub fn name(self) -> &'static str {
        match self {
            State::Starting => "starting",
            State::Serving => "serving",
            State::Quiescing => "quiescing",
            State::Guarding => "guarding",
            State::Restarting => "restarting",
            State::Draining => "draining",
        }
    }

    fn from_u64(x: u64) -> State {
        match x {
            1 => State::Serving,
            2 => State::Quiescing,
            3 => State::Guarding,
            4 => State::Restarting,
            5 => State::Draining,
            _ => State::Starting,
        }
    }

    /// May `/readyz` report ready? Only the states where the scheduler is
    /// actually decoding: a quiesce or restart is usually shorter than a
    /// probe interval, but load balancers that do catch it should steer
    /// new work elsewhere until the segment is back.
    pub fn ready(self) -> bool {
        matches!(self, State::Serving | State::Guarding)
    }
}

/// An operator's reload request (the admin endpoint's mailbox payload).
#[derive(Clone, Debug)]
pub struct ReloadSpec {
    /// Manifest model name to stage (usually the serving name, re-exported
    /// in place by the training pipeline).
    pub model: String,
}

/// Outcome record of the most recent swap attempt, for the status surface.
#[derive(Clone, Debug)]
pub struct SwapRecord {
    pub model: String,
    /// "adopted" | "rejected" | "rolled_back".
    pub outcome: &'static str,
    /// Failure cause or rollback trigger; empty for clean adoptions.
    pub detail: String,
    /// Serving generation after the attempt resolved.
    pub generation: u64,
}

/// What is serving right now.
#[derive(Clone, Debug)]
struct ServingInfo {
    model: String,
    fingerprint: u64,
    params: usize,
}

/// Per-request resume record, fed by the coordinator while a lifecycle
/// handle is attached. This is the panic-survival ledger: everything
/// needed to rebuild a request in a fresh scheduler loop, kept OUTSIDE
/// the loop that can die. Fidelity is correctness-first: sequence,
/// sampling state, streaming offset and deadline are exact; latency
/// bookkeeping (TTFT instant, ITL gaps, depth histogram) restarts, so a
/// restarted request's timing metrics undercount — never its tokens.
struct RegEntry {
    prompt: Vec<u32>,
    emitted: Vec<u32>,
    sampling: crate::config::SamplingConfig,
    max_new: usize,
    enqueued: Instant,
    deadline_at: Option<Instant>,
    events: Option<Sender<crate::coordinator::Delta>>,
    tag: Option<String>,
    started: bool,
    streamed: usize,
    /// RNG snapshot from the end of the last completed block; `None`
    /// until the first block (recomputed from the seed — no draws yet).
    rng: Option<Pcg64>,
}

/// Shared lifecycle handle: the admin endpoints, the scheduler loop and
/// the supervisor all hold one `Arc<Lifecycle>`.
pub struct Lifecycle {
    state: AtomicU64,
    /// Monotonic count of serving-draft changes (adoptions + rollbacks),
    /// starting at 1 for the boot bundle. The `specd_draft_generation`
    /// gauge.
    generation: AtomicU64,
    /// Fast-path flag for the mailbox: one relaxed load per scheduler
    /// iteration when no reload is pending.
    reload_armed: AtomicBool,
    reload: Mutex<Option<ReloadSpec>>,
    serving: Mutex<ServingInfo>,
    last_swap: Mutex<Option<SwapRecord>>,
    swaps_adopted: AtomicU64,
    swaps_rejected: AtomicU64,
    swaps_rolled_back: AtomicU64,
    scheduler_restarts: AtomicU64,
    /// Chaos hook: the next scheduler iteration panics (tests the
    /// supervisor restart path end to end).
    panic_trip: AtomicBool,
    registry: Mutex<BTreeMap<u64, RegEntry>>,
}

impl Lifecycle {
    pub fn new(model: &str, fingerprint: u64, params: usize) -> Lifecycle {
        Lifecycle {
            state: AtomicU64::new(State::Starting as u64),
            generation: AtomicU64::new(1),
            reload_armed: AtomicBool::new(false),
            reload: Mutex::new(None),
            serving: Mutex::new(ServingInfo {
                model: model.to_string(),
                fingerprint,
                params,
            }),
            last_swap: Mutex::new(None),
            swaps_adopted: AtomicU64::new(0),
            swaps_rejected: AtomicU64::new(0),
            swaps_rolled_back: AtomicU64::new(0),
            scheduler_restarts: AtomicU64::new(0),
            panic_trip: AtomicBool::new(false),
            registry: Mutex::new(BTreeMap::new()),
        }
    }

    fn lock<'l, T>(m: &'l Mutex<T>) -> MutexGuard<'l, T> {
        m.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn state(&self) -> State {
        State::from_u64(self.state.load(Ordering::Acquire))
    }

    pub fn set_state(&self, s: State) {
        self.state.store(s as u64, Ordering::Release);
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// (model, weights fingerprint, parameter count) serving right now.
    pub fn serving(&self) -> (String, u64, usize) {
        let s = Self::lock(&self.serving);
        (s.model.clone(), s.fingerprint, s.params)
    }

    /// Fill in the serving identity without touching the generation
    /// counter (boot-time: the handle is created before models load).
    pub fn set_serving(&self, model: &str, fingerprint: u64, params: usize) {
        *Self::lock(&self.serving) = ServingInfo {
            model: model.to_string(),
            fingerprint,
            params,
        };
    }

    pub fn last_swap(&self) -> Option<SwapRecord> {
        Self::lock(&self.last_swap).clone()
    }

    /// (adopted, rejected, rolled_back, scheduler_restarts).
    pub fn counters(&self) -> (u64, u64, u64, u64) {
        (
            self.swaps_adopted.load(Ordering::Relaxed),
            self.swaps_rejected.load(Ordering::Relaxed),
            self.swaps_rolled_back.load(Ordering::Relaxed),
            self.scheduler_restarts.load(Ordering::Relaxed),
        )
    }

    // ---- reload mailbox ---------------------------------------------------

    /// Arm a reload. Returns `false` (HTTP 409) when one is already
    /// pending — the mailbox holds exactly one spec.
    pub fn request_reload(&self, spec: ReloadSpec) -> bool {
        let mut slot = Self::lock(&self.reload);
        if slot.is_some() {
            return false;
        }
        *slot = Some(spec);
        self.reload_armed.store(true, Ordering::Release);
        true
    }

    pub fn pending_reload(&self) -> Option<String> {
        Self::lock(&self.reload).as_ref().map(|s| s.model.clone())
    }

    /// Scheduler-side: claim the pending reload, if any. One relaxed load
    /// on the hot path when the mailbox is empty.
    pub fn take_reload(&self) -> Option<ReloadSpec> {
        if !self.reload_armed.load(Ordering::Relaxed) {
            return None;
        }
        let spec = Self::lock(&self.reload).take();
        self.reload_armed.store(false, Ordering::Release);
        spec
    }

    // ---- swap/restart accounting (trace instants live here so the
    //      counters and the flight recorder cannot drift apart) ----------

    pub fn record_adopted(&self, model: &str, fingerprint: u64, params: usize, guarded: bool) {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        *Self::lock(&self.serving) = ServingInfo {
            model: model.to_string(),
            fingerprint,
            params,
        };
        self.swaps_adopted.fetch_add(1, Ordering::Relaxed);
        *Self::lock(&self.last_swap) = Some(SwapRecord {
            model: model.to_string(),
            outcome: "adopted",
            detail: String::new(),
            generation,
        });
        self.set_state(if guarded { State::Guarding } else { State::Serving });
        crate::trace::swap(generation, 0);
    }

    pub fn record_rejected(&self, model: &str, error: &str) {
        self.swaps_rejected.fetch_add(1, Ordering::Relaxed);
        let generation = self.generation();
        *Self::lock(&self.last_swap) = Some(SwapRecord {
            model: model.to_string(),
            outcome: "rejected",
            detail: error.to_string(),
            generation,
        });
        crate::trace::swap(generation, 1);
    }

    /// `reason` uses the trace encoding: 0 drift, 1 accept floor,
    /// 2 breaker open.
    pub fn record_rolled_back(&self, restored_model: &str, fingerprint: u64, params: usize, reason: u64) {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        *Self::lock(&self.serving) = ServingInfo {
            model: restored_model.to_string(),
            fingerprint,
            params,
        };
        self.swaps_rolled_back.fetch_add(1, Ordering::Relaxed);
        let detail = match reason {
            0 => "drift",
            1 => "accept_floor",
            _ => "breaker_open",
        };
        *Self::lock(&self.last_swap) = Some(SwapRecord {
            model: restored_model.to_string(),
            outcome: "rolled_back",
            detail: detail.to_string(),
            generation,
        });
        self.set_state(State::Serving);
        crate::trace::rollback(generation, reason);
    }

    pub fn record_restart(&self, readmitted: u64) {
        let n = self.scheduler_restarts.fetch_add(1, Ordering::Relaxed) + 1;
        crate::trace::sched_restart(n, readmitted);
    }

    // ---- chaos hook -------------------------------------------------------

    /// Make the next scheduler iteration panic (supervision test hook;
    /// wired to the debug endpoints, never to normal operation).
    pub fn trip_scheduler_panic(&self) {
        self.panic_trip.store(true, Ordering::Release);
    }

    pub fn take_panic_trip(&self) -> bool {
        self.panic_trip.swap(false, Ordering::AcqRel)
    }

    // ---- resume registry --------------------------------------------------

    pub fn register(&self, req: &Request, enqueued: Instant, deadline_at: Option<Instant>) {
        Self::lock(&self.registry).insert(
            req.id,
            RegEntry {
                prompt: req.prompt.clone(),
                emitted: Vec::new(),
                sampling: req.sampling,
                max_new: req.max_new,
                enqueued,
                deadline_at,
                events: req.events.clone(),
                tag: req.tag.clone(),
                started: false,
                streamed: 0,
                rng: None,
            },
        );
    }

    pub fn note_started(&self, id: u64) {
        if let Some(e) = Self::lock(&self.registry).get_mut(&id) {
            e.started = true;
        }
    }

    /// Record one completed block: tokens appended to the resume sequence,
    /// the post-block RNG snapshot, and the streamed offset.
    pub fn note_block(&self, id: u64, emitted: &[u32], rng: &Pcg64, streamed: usize) {
        if let Some(e) = Self::lock(&self.registry).get_mut(&id) {
            e.emitted.extend_from_slice(emitted);
            e.rng = Some(rng.clone());
            e.streamed = streamed;
        }
    }

    /// A terminal fired for this request — it no longer needs resuming.
    pub fn unregister(&self, id: u64) {
        Self::lock(&self.registry).remove(&id);
    }

    pub fn registry_len(&self) -> usize {
        Self::lock(&self.registry).len()
    }

    /// Consume the registry into resume records (ascending id, so restart
    /// re-admission order is deterministic). Used only on the panic path;
    /// clean swap exits carry full-fidelity state out of the loop instead.
    pub fn drain_registry(&self) -> Vec<ResumeState> {
        let map = std::mem::take(&mut *Self::lock(&self.registry));
        map.into_iter()
            .map(|(id, e)| {
                let rng = e
                    .rng
                    .unwrap_or_else(|| Pcg64::with_stream(e.sampling.seed ^ id, 0x5e0e));
                let mut seq = e.prompt;
                let prompt_len = seq.len();
                seq.extend_from_slice(&e.emitted);
                ResumeState {
                    id,
                    seq,
                    prompt_len,
                    sampling: e.sampling,
                    max_new: e.max_new,
                    rng,
                    enqueued: e.enqueued,
                    first_token: None,
                    deadline_at: e.deadline_at,
                    events: e.events,
                    streamed: e.streamed,
                    depth_counts: Vec::new(),
                    tag: e.tag,
                    last_emit: None,
                    itl: Vec::new(),
                    salvages: 0,
                    clean_blocks: 0,
                    stats: Default::default(),
                    capture: None,
                    started: e.started,
                }
            })
            .collect()
    }

    // ---- metrics ----------------------------------------------------------

    /// Lifecycle Prometheus families, appended to the `/metrics` scrape.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        prom_gauge(
            &mut out,
            "specd_draft_generation",
            "Serving-draft generation: bumps on every adoption and rollback (boot bundle = 1).",
            self.generation() as f64,
        );
        let fam = "specd_draft_swaps_total";
        out.push_str(&format!(
            "# HELP {fam} Draft-bundle swap attempts by outcome.\n# TYPE {fam} counter\n"
        ));
        for (outcome, v) in [
            ("adopted", &self.swaps_adopted),
            ("rejected", &self.swaps_rejected),
            ("rolled_back", &self.swaps_rolled_back),
        ] {
            out.push_str(&format!(
                "{fam}{{outcome=\"{outcome}\"}} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        prom_counter(
            &mut out,
            "specd_scheduler_restarts_total",
            "Supervisor restarts of the scheduler loop after a panic.",
            self.scheduler_restarts.load(Ordering::Relaxed) as f64,
        );
        out
    }
}

// ---- supervisor ------------------------------------------------------------

/// Everything the supervisor needs besides the models: where to stage
/// candidate bundles from and what to attach to each serving segment's
/// coordinator.
pub struct SupervisorCtx<'a> {
    pub rt: &'a Runtime,
    /// Artifact directory reloads re-read their manifest from.
    pub artifacts_dir: &'a str,
    /// The serving draft's compiled architecture — staged bundles reuse
    /// its executables, so they must match it exactly.
    pub draft_arch: &'a Arc<CompiledArch>,
    /// Serving vocabulary hash; staged bundles must match.
    pub vocab_hash: &'a str,
    pub target: &'a Model,
    pub cfg: &'a RunConfig,
    pub lifecycle: &'a Arc<Lifecycle>,
    /// Re-bound onto every adopted draft so degraded-mode detection and
    /// the breaker-open rollback trigger survive swaps.
    pub draft_breaker: Option<Arc<crate::faults::Breaker>>,
    pub gauges: Option<Arc<crate::metrics::SchedulerGauges>>,
    pub telemetry: Option<Arc<crate::telemetry::Telemetry>>,
    pub log_requests: bool,
}

/// Serve until the request channel closes, supervising the scheduler
/// loop: each iteration of this outer loop is one serving *segment*
/// (one `Coordinator::serve_supervised` call) ending in a drain, a
/// draft swap, a rollback, or a panic. Models are owned HERE, outside
/// the loop that can die, so a panic or a swap never loses them.
pub fn run_supervised(
    ctx: &SupervisorCtx<'_>,
    mut draft: Model,
    rx: &Receiver<Request>,
    tx: &Sender<Response>,
) -> Result<ServeMetrics> {
    let mut merged = ServeMetrics::default();
    // Last-known-good: the previous serving draft, retained across a
    // guarded adoption so rollback is a swap back, not a reload.
    let mut lkg: Option<Model> = None;
    let mut resume: Vec<ResumeState> = Vec::new();
    let mut guard: Option<GuardSpec> = None;
    let mut restarts: Vec<Instant> = Vec::new();
    // The supervisor is the first code that sees the loaded draft, so it
    // fills in the serving identity (the lifecycle handle is created at
    // the HTTP edge before any model loads).
    ctx.lifecycle.set_serving(&draft.name, draft.fingerprint, draft.params);
    if ctx.lifecycle.state() == State::Starting {
        ctx.lifecycle.set_state(State::Serving);
    }
    loop {
        // The staged model is parked here by the stager closure, which
        // runs ON the scheduler thread (PJRT handles are not Send) but
        // must outlive the segment that staged it.
        let mut staged: Option<Model> = None;
        let mut staged_name = String::new();
        let outcome = {
            let decoder = SpecDecoder::new(&draft, ctx.target, ctx.cfg.gamma)?;
            let mut coord = Coordinator::new(decoder, ctx.cfg.clone())?
                .with_lifecycle(ctx.lifecycle.clone())
                .with_access_log(ctx.log_requests);
            if let Some(g) = &ctx.gauges {
                coord = coord.with_gauges(g.clone());
            }
            if let Some(t) = &ctx.telemetry {
                coord = coord.with_telemetry(t.clone());
            }
            let seg_resume = std::mem::take(&mut resume);
            let seg_guard = guard.take();
            let mut stager = |spec: &ReloadSpec| -> Result<()> {
                let m = ctx.rt.stage_draft(
                    ctx.artifacts_dir,
                    ctx.draft_arch,
                    ctx.vocab_hash,
                    &spec.model,
                )?;
                staged_name = spec.model.clone();
                staged = Some(m);
                Ok(())
            };
            std::panic::catch_unwind(AssertUnwindSafe(|| {
                coord.serve_supervised(rx, tx, seg_resume, Some(&mut stager), seg_guard)
            }))
        };
        match outcome {
            Ok(Ok(out)) => {
                merged.merge(&out.metrics);
                match out.exit {
                    Exit::Drained => return Ok(merged),
                    Exit::Swap => {
                        let Some(mut adopted) = staged else {
                            // Defensive: a swap exit without a staged
                            // model resumes on the current draft.
                            resume = out.residents;
                            continue;
                        };
                        if let Some(b) = &ctx.draft_breaker {
                            adopted.set_breaker(b.clone());
                        }
                        // Guard baselines are captured at adoption so the
                        // triggers fire on what the NEW draft does, not on
                        // conditions it inherited.
                        let drift_at_entry =
                            ctx.telemetry.as_ref().is_some_and(|t| t.drift_active());
                        let opens_at_entry =
                            ctx.draft_breaker.as_ref().map(|b| b.opens()).unwrap_or(0);
                        let fingerprint = adopted.fingerprint;
                        let params = adopted.params;
                        lkg = Some(std::mem::replace(&mut draft, adopted));
                        let guarded = ctx.cfg.swap_guard_blocks > 0;
                        ctx.lifecycle.record_adopted(&staged_name, fingerprint, params, guarded);
                        if guarded {
                            guard = Some(GuardSpec {
                                guard_blocks: ctx.cfg.swap_guard_blocks,
                                accept_floor: ctx.cfg.swap_accept_floor,
                                drift_at_entry,
                                opens_at_entry,
                            });
                        }
                        resume = out.residents;
                    }
                    Exit::Rollback(reason) => {
                        if let Some(prev) = lkg.take() {
                            draft = prev;
                        }
                        ctx.lifecycle.record_rolled_back(
                            &draft.name,
                            draft.fingerprint,
                            draft.params,
                            reason,
                        );
                        resume = out.residents;
                    }
                }
            }
            Ok(Err(e)) => {
                // Scheduler-fatal error (not a panic): requests that never
                // reached their terminal are stranded with exactly one
                // error terminal each, then the failure propagates.
                let stranded = ctx.lifecycle.drain_registry();
                for r in &stranded {
                    coordinator::strand_terminal(tx, r, &format!("scheduler failed: {e}"));
                }
                return Err(e);
            }
            Err(_panic) => {
                let now = Instant::now();
                restarts.retain(|t| now.duration_since(*t) < RESTART_STORM_WINDOW);
                restarts.push(now);
                ctx.lifecycle.set_state(State::Restarting);
                if restarts.len() > RESTART_STORM_CAP {
                    let stranded = ctx.lifecycle.drain_registry();
                    ctx.lifecycle.record_restart(0);
                    for r in &stranded {
                        coordinator::strand_terminal(
                            tx,
                            r,
                            "scheduler restart storm: crash loop, request stranded",
                        );
                    }
                    return Err(Error::Scheduler(format!(
                        "scheduler panicked {} times inside {:?}; giving up",
                        restarts.len(),
                        RESTART_STORM_WINDOW
                    )));
                }
                // Rebuild the loop from the registry: a fresh segment gets
                // a fresh BatchedCtx and slot pool, and every registered
                // request is re-admitted (started ones re-prefill + resume
                // mid-stream, queued ones go back to pending).
                resume = ctx.lifecycle.drain_registry();
                ctx.lifecycle.record_restart(resume.len() as u64);
                eprintln!(
                    "specd: scheduler panicked; restarting with {} resident request(s)",
                    resume.len()
                );
                // A panic mid-guard loses the guard's block counters;
                // the conservative choice is to keep serving the new
                // draft unguarded rather than roll back on partial data.
                guard = None;
                ctx.lifecycle.set_state(State::Serving);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SamplingConfig;

    fn lc() -> Lifecycle {
        Lifecycle::new("draft_a", 0xfeed, 1234)
    }

    #[test]
    fn state_roundtrip_and_readiness() {
        let l = lc();
        assert_eq!(l.state(), State::Starting);
        assert!(!l.state().ready());
        for s in [
            State::Serving,
            State::Quiescing,
            State::Guarding,
            State::Restarting,
            State::Draining,
        ] {
            l.set_state(s);
            assert_eq!(l.state(), s);
            assert_eq!(State::from_u64(s as u64), s);
        }
        assert!(State::Serving.ready() && State::Guarding.ready());
        assert!(!State::Quiescing.ready() && !State::Restarting.ready() && !State::Draining.ready());
    }

    #[test]
    fn reload_mailbox_holds_exactly_one() {
        let l = lc();
        assert!(l.take_reload().is_none());
        assert!(l.request_reload(ReloadSpec { model: "draft_b".into() }));
        assert_eq!(l.pending_reload().as_deref(), Some("draft_b"));
        assert!(!l.request_reload(ReloadSpec { model: "draft_c".into() }), "409 while pending");
        let spec = l.take_reload().expect("armed");
        assert_eq!(spec.model, "draft_b");
        assert!(l.take_reload().is_none(), "mailbox drained");
        assert!(l.pending_reload().is_none());
        assert!(l.request_reload(ReloadSpec { model: "draft_c".into() }), "re-armable");
    }

    #[test]
    fn swap_accounting_generation_and_counters() {
        let l = lc();
        assert_eq!(l.generation(), 1);
        l.record_rejected("draft_bad", "golden probe mismatch");
        assert_eq!(l.generation(), 1, "rejection never bumps the generation");
        l.record_adopted("draft_b", 0xbeef, 999, true);
        assert_eq!(l.generation(), 2);
        assert_eq!(l.state(), State::Guarding);
        assert_eq!(l.serving().0, "draft_b");
        assert_eq!(l.serving().1, 0xbeef);
        l.record_rolled_back("draft_a", 0xfeed, 1234, 1);
        assert_eq!(l.generation(), 3, "rollback is a serving change too");
        assert_eq!(l.state(), State::Serving);
        assert_eq!(l.serving().0, "draft_a");
        let (adopted, rejected, rolled_back, restarts) = l.counters();
        assert_eq!((adopted, rejected, rolled_back, restarts), (1, 1, 1, 0));
        let last = l.last_swap().expect("recorded");
        assert_eq!(last.outcome, "rolled_back");
        assert_eq!(last.detail, "accept_floor");
        l.record_restart(2);
        assert_eq!(l.counters().3, 1);
    }

    #[test]
    fn panic_trip_fires_once() {
        let l = lc();
        assert!(!l.take_panic_trip());
        l.trip_scheduler_panic();
        assert!(l.take_panic_trip());
        assert!(!l.take_panic_trip(), "one trip, one panic");
    }

    #[test]
    fn registry_roundtrip_and_drain() {
        let l = lc();
        let mut req = Request::new(9, vec![1, 2, 3], 8, SamplingConfig::greedy());
        req.tag = Some("xsum".into());
        let now = Instant::now();
        l.register(&req, now, None);
        // A second, never-started request drains as re-queueable.
        l.register(&Request::new(4, vec![7], 2, SamplingConfig::greedy()), now, None);
        assert_eq!(l.registry_len(), 2);
        l.note_started(9);
        let rng = Pcg64::with_stream(9, 0x5e0e);
        l.note_block(9, &[5, 6], &rng, 2);
        let drained = l.drain_registry();
        assert_eq!(l.registry_len(), 0, "drain consumes the registry");
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].id, 4, "ascending id order");
        assert!(!drained[0].started);
        assert_eq!(drained[0].seq, vec![7]);
        let r = &drained[1];
        assert!(r.started);
        assert_eq!(r.seq, vec![1, 2, 3, 5, 6], "seq = prompt ++ emitted");
        assert_eq!(r.prompt_len, 3);
        assert_eq!(r.streamed, 2);
        assert_eq!(r.tag.as_deref(), Some("xsum"));
    }

    #[test]
    fn unregister_removes_terminated_requests() {
        let l = lc();
        let now = Instant::now();
        l.register(&Request::new(1, vec![1], 4, SamplingConfig::greedy()), now, None);
        l.register(&Request::new(2, vec![2], 4, SamplingConfig::greedy()), now, None);
        l.unregister(1);
        let drained = l.drain_registry();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].id, 2);
    }

    #[test]
    fn prometheus_families_render() {
        let l = lc();
        l.record_adopted("draft_b", 1, 2, false);
        l.record_rejected("draft_c", "bad magic");
        let text = l.prometheus_text();
        for fam in [
            "specd_draft_generation",
            "specd_draft_swaps_total",
            "specd_scheduler_restarts_total",
        ] {
            assert!(text.contains(&format!("# TYPE {fam}")), "missing {fam}");
        }
        assert!(text.contains("specd_draft_generation 2"));
        assert!(text.contains("specd_draft_swaps_total{outcome=\"adopted\"} 1"));
        assert!(text.contains("specd_draft_swaps_total{outcome=\"rejected\"} 1"));
        assert!(text.contains("specd_draft_swaps_total{outcome=\"rolled_back\"} 0"));
        assert!(text.contains("specd_scheduler_restarts_total 0"));
    }
}

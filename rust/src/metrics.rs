//! Evaluation metrics — the paper's §3 definitions, plus serving metrics.
//!
//! * block efficiency τ: average tokens generated per target-model run
//!   (per "block"); for block size γ, τ(x) ∈ [1, γ+1].
//! * MBSU (memory-bound speed-up): the paper prints `MBSU = c·τ/(c·γ+1)`,
//!   which is dimensionally a *slow-down* for c ≪ 1 and inconsistent with
//!   its own "hypothetical speed-up" definition; the standard derivation
//!   (draft costs c per token, target costs 1 per block under a
//!   memory-bound latency model) gives `MBSU = τ / (c·γ + 1)`, which also
//!   matches the magnitudes the paper reports (~2x). We implement the
//!   corrected form and record the discrepancy in EXPERIMENTS.md.
//! * token-rate ratio: SD tokens/sec over autoregressive tokens/sec,
//!   measured on this testbed.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use crate::benchkit::Stats;

/// Counters accumulated by the speculative decoding engine.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpecStats {
    /// Target-model verify runs ("blocks").
    pub blocks: usize,
    /// Draft tokens proposed.
    pub drafted: usize,
    /// Draft tokens accepted by verification.
    pub accepted: usize,
    /// Total new tokens emitted (accepted + corrected/bonus tokens).
    pub generated: usize,
    /// Draft-model executions (decode steps + sync chunks).
    pub draft_calls: usize,
    /// Target-model executions (prefill chunks + verifies).
    pub target_calls: usize,
}

impl SpecStats {
    pub fn merge(&mut self, other: &SpecStats) {
        self.blocks += other.blocks;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.generated += other.generated;
        self.draft_calls += other.draft_calls;
        self.target_calls += other.target_calls;
    }

    /// Block efficiency τ = generated tokens per block.
    pub fn block_efficiency(&self) -> f64 {
        if self.blocks == 0 {
            0.0
        } else {
            self.generated as f64 / self.blocks as f64
        }
    }

    /// Empirical acceptance rate of drafted tokens.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Clip the generated-token counter to the number of tokens actually
    /// delivered. The last block can overshoot a request's `max_new`
    /// budget; the overshoot is truncated from the output, and counting it
    /// would inflate reported block efficiency relative to what the caller
    /// received.
    pub fn clip_to_delivered(&mut self, delivered: usize) {
        if self.generated > delivered {
            self.generated = delivered;
        }
    }
}

/// Memory-bound speed-up for block efficiency `tau`, relative draft cost
/// `c` (param ratio) and draft length `gamma` (corrected formula — see
/// module docs).
pub fn mbsu(tau: f64, c: f64, gamma: usize) -> f64 {
    tau / (c * gamma as f64 + 1.0)
}

/// The paper's literal formula, kept for the EXPERIMENTS.md comparison.
pub fn mbsu_paper_literal(tau: f64, c: f64, gamma: usize) -> f64 {
    c * tau / (c * gamma as f64 + 1.0)
}

/// Wall-clock token-rate measurement for one decoding run.
#[derive(Debug, Clone, Copy)]
pub struct RateMeasurement {
    pub new_tokens: usize,
    pub elapsed: Duration,
}

impl RateMeasurement {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.elapsed.is_zero() {
            0.0
        } else {
            self.new_tokens as f64 / self.elapsed.as_secs_f64()
        }
    }
}

/// Token-rate ratio SD/AR (> 1 means speculative decoding is faster).
pub fn token_rate_ratio(sd: &RateMeasurement, ar: &RateMeasurement) -> f64 {
    let a = ar.tokens_per_sec();
    if a == 0.0 {
        0.0
    } else {
        sd.tokens_per_sec() / a
    }
}

/// Cap on retained per-request latency/TTFT samples in a long-running
/// aggregate: [`ServeMetrics::merge`] keeps a sliding window of the most
/// recent samples so the live `/metrics` aggregate cannot grow without
/// bound (quantiles are then over this window; lifetime totals stay in
/// the counters).
pub const LATENCY_WINDOW: usize = 4096;

/// Emit one Prometheus counter family (HELP/TYPE/sample lines). Shared by
/// [`ServeMetrics::prometheus_text`] and the HTTP server's own counters so
/// the exposition format lives in one place.
pub fn prom_counter(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
}

/// Emit one Prometheus gauge family.
pub fn prom_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
}

/// Upper bounds for per-phase block-seconds histograms (seconds).
pub const BLOCK_SECONDS_BOUNDS: [f64; 8] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25];

/// Upper bounds for the admission queue-wait histogram (seconds).
pub const QUEUE_WAIT_BOUNDS: [f64; 8] = [0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0];

/// Upper bounds for the time-to-first-token histogram (seconds).
pub const TTFT_BOUNDS: [f64; 10] = [0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0];

/// Upper bounds for the inter-token-latency histogram (seconds).
pub const ITL_BOUNDS: [f64; 10] = [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0];

/// A real Prometheus histogram: fixed finite upper bounds plus the
/// implicit `+Inf` overflow bucket, exposed in cumulative
/// `_bucket`/`_sum`/`_count` form. Unlike the windowed quantile
/// summaries ([`ServeMetrics::prometheus_text`]), bucket counts are
/// lifetime-monotonic, so quantiles survive scrape resets and can be
/// aggregated across instances.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    /// Finite upper bounds, ascending and deduplicated.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `counts[bounds.len()]` = +Inf.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    pub fn with_bounds(bounds: &[f64]) -> Histogram {
        let mut b: Vec<f64> = bounds.iter().copied().filter(|v| v.is_finite()).collect();
        b.sort_by(|x, y| x.partial_cmp(y).expect("finite bounds"));
        b.dedup();
        Histogram { counts: vec![0; b.len() + 1], bounds: b, sum: 0.0, count: 0 }
    }

    /// Integer buckets `0, 1, ..., gamma` for accepted-drafts-per-block
    /// depth (a block can accept anywhere in `0..=gamma`).
    pub fn accept_depth(gamma: usize) -> Histogram {
        let bounds: Vec<f64> = (0..=gamma).map(|i| i as f64).collect();
        Histogram::with_bounds(&bounds)
    }

    pub fn observe(&mut self, v: f64) {
        self.observe_n(v, 1);
    }

    /// Record `n` observations of the same value in O(1) (pre-bucketed
    /// sources like [`crate::coordinator::Response::depth_counts`]).
    pub fn observe_n(&mut self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; self.bounds.len() + 1];
        }
        let i = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[i] += n;
        self.sum += v * n as f64;
        self.count += n;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Fold another histogram in. Identical layouts add bucket-wise; an
    /// uninitialized side adopts the other's layout; mismatched layouts
    /// (shouldn't happen within one process) re-bucket the other side's
    /// counts at their upper bounds so nothing is silently dropped.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 && other.bounds.is_empty() {
            return;
        }
        if self.bounds.is_empty() && self.count == 0 {
            *self = other.clone();
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; self.bounds.len() + 1];
        }
        if self.bounds == other.bounds {
            for (a, b) in self.counts.iter_mut().zip(&other.counts) {
                *a += b;
            }
        } else {
            for (i, &c) in other.counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let v = other.bounds.get(i).copied().unwrap_or(f64::INFINITY);
                let j = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
                self.counts[j] += c;
            }
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Emit this histogram's sample lines for an already-headed family.
    /// `label` is a ready label pair like `phase="verify"` (must contain
    /// no spaces) or `""` for an unlabeled series.
    fn render_series(&self, out: &mut String, name: &str, label: &str) {
        let sep = if label.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (i, b) in self.bounds.iter().enumerate() {
            cum += self.counts.get(i).copied().unwrap_or(0);
            out.push_str(&format!("{name}_bucket{{{label}{sep}le=\"{b}\"}} {cum}\n"));
        }
        out.push_str(&format!("{name}_bucket{{{label}{sep}le=\"+Inf\"}} {}\n", self.count));
        if label.is_empty() {
            out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", self.sum, self.count));
        } else {
            out.push_str(&format!("{name}_sum{{{label}}} {}\n", self.sum));
            out.push_str(&format!("{name}_count{{{label}}} {}\n", self.count));
        }
    }
}

/// Emit one Prometheus histogram family: one HELP/TYPE header, then one
/// series of `_bucket`/`_sum`/`_count` lines per `(label, histogram)`
/// pair (label `""` = unlabeled).
pub fn prom_histogram(out: &mut String, name: &str, help: &str, series: &[(&str, &Histogram)]) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for (label, h) in series {
        h.render_series(out, name, label);
    }
}

/// Latency/throughput aggregation for the serving benchmark.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Per-request end-to-end latency (seconds).
    pub request_latency: Vec<f64>,
    /// Per-request time-to-first-token (seconds).
    pub ttft: Vec<f64>,
    pub total_new_tokens: usize,
    pub total_requests: usize,
    /// Requests evicted for exceeding their deadline (HTTP 408).
    pub timeouts: usize,
    /// Requests cancelled because the streaming client disconnected.
    pub cancelled: usize,
    pub wall_seconds: f64,
    pub spec: SpecStats,
    /// Scheduler iterations (one lockstep batch step across all lanes).
    pub batch_iterations: usize,
    /// Wall-clock seconds summed per lockstep phase across iterations.
    pub phase_draft_sync_seconds: f64,
    pub phase_propose_seconds: f64,
    pub phase_verify_seconds: f64,
    /// PJRT executable launches issued by the scheduler's batch steps.
    /// The fused batched path spends O(γ + 2) per step; per-lane dispatch
    /// spends O(N·(γ + 2)) — this counter is how the difference shows.
    pub dispatches: u64,
    /// Lanes that emitted a block, summed over iterations
    /// (`lane_steps / batch_iterations` = mean batch occupancy).
    pub lane_steps: usize,
    /// Of those, lane-steps served by fused batched dispatch.
    pub batched_lane_steps: usize,
    /// Iterations that began with queued requests and an exhausted slot
    /// pool (admission deferred, not errored).
    pub admission_deferrals: usize,
    /// High-water mark of live slots in the scheduler's KV pool.
    pub pool_peak_slots: usize,
    /// Fused admission waves executed (batched direct-to-lane prefill).
    pub prefill_waves: usize,
    /// Lanes admitted through fused waves (`/ prefill_waves` = mean wave
    /// width; requests admitted per-lane as fallback are not counted).
    pub prefill_wave_lanes: usize,
    /// PJRT executable launches issued by admission prefill (wave and
    /// per-lane fallback alike). A wave of N ragged prompts costs
    /// O(ceil(L_max/block)) fused dispatches; the pre-wave path cost
    /// O(Σ ceil(L_i/block)) + N packs.
    pub prefill_dispatches: u64,
    /// Prompt tokens prefilled at admission.
    pub prefill_tokens: usize,
    /// Wall seconds in the admission-prefill phase.
    pub phase_prefill_seconds: f64,
    /// Windowed per-request queue-wait samples, seconds (enqueue → the
    /// request's prefill starting).
    pub queue_wait: Vec<f64>,
    /// Accepted drafts per speculation block (0..=γ integer buckets) —
    /// the per-position acceptance view behind `specd_accept_depth`.
    pub accept_depth: Histogram,
    /// Per-iteration engine-phase wall seconds (`specd_block_seconds`).
    pub block_draft_sync: Histogram,
    pub block_propose: Histogram,
    pub block_verify: Histogram,
    /// Unwindowed queue-wait histogram: unlike the [`Self::queue_wait`]
    /// summary window, bucket counts survive scrape resets.
    pub queue_wait_hist: Histogram,
    /// Windowed inter-token-latency samples: the mean gap between
    /// consecutive emitted tokens (after the first) per block.
    pub itl: Vec<f64>,
    /// Unwindowed TTFT histogram (`specd_ttft_seconds`); quantiles
    /// survive scrape resets, unlike the old summary view.
    pub ttft_hist: Histogram,
    /// Unwindowed inter-token-latency histogram (`specd_itl_seconds`).
    pub itl_hist: Histogram,
}

impl ServeMetrics {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.total_new_tokens as f64 / self.wall_seconds
        }
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.total_requests as f64 / self.wall_seconds
        }
    }

    pub fn latency_stats(&self) -> Option<Stats> {
        if self.request_latency.is_empty() {
            None
        } else {
            Some(Stats::from(self.request_latency.clone()))
        }
    }

    pub fn ttft_stats(&self) -> Option<Stats> {
        if self.ttft.is_empty() {
            None
        } else {
            Some(Stats::from(self.ttft.clone()))
        }
    }

    pub fn queue_wait_stats(&self) -> Option<Stats> {
        if self.queue_wait.is_empty() {
            None
        } else {
            Some(Stats::from(self.queue_wait.clone()))
        }
    }

    /// Mean lanes per fused admission wave (0 with no waves).
    pub fn mean_wave_lanes(&self) -> f64 {
        if self.prefill_waves == 0 {
            0.0
        } else {
            self.prefill_wave_lanes as f64 / self.prefill_waves as f64
        }
    }

    /// Mean lanes emitting per batch step (0 with no iterations).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batch_iterations == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batch_iterations as f64
        }
    }

    /// Mean PJRT dispatches per batch step (0 with no iterations).
    pub fn dispatches_per_step(&self) -> f64 {
        if self.batch_iterations == 0 {
            0.0
        } else {
            self.dispatches as f64 / self.batch_iterations as f64
        }
    }

    /// Merge another aggregation into this one (the HTTP server folds each
    /// completed request's view into a shared live aggregate). Retained
    /// samples are windowed to the last [`LATENCY_WINDOW`] so a
    /// long-running server's aggregate stays O(1) in memory and /metrics
    /// scrape cost stays bounded; the scalar totals are lifetime-exact.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.request_latency.extend_from_slice(&other.request_latency);
        self.ttft.extend_from_slice(&other.ttft);
        self.queue_wait.extend_from_slice(&other.queue_wait);
        self.itl.extend_from_slice(&other.itl);
        for v in
            [&mut self.request_latency, &mut self.ttft, &mut self.queue_wait, &mut self.itl]
        {
            if v.len() > LATENCY_WINDOW {
                v.drain(..v.len() - LATENCY_WINDOW);
            }
        }
        self.total_new_tokens += other.total_new_tokens;
        self.total_requests += other.total_requests;
        self.timeouts += other.timeouts;
        self.cancelled += other.cancelled;
        self.wall_seconds += other.wall_seconds;
        self.spec.merge(&other.spec);
        self.batch_iterations += other.batch_iterations;
        self.phase_draft_sync_seconds += other.phase_draft_sync_seconds;
        self.phase_propose_seconds += other.phase_propose_seconds;
        self.phase_verify_seconds += other.phase_verify_seconds;
        self.dispatches += other.dispatches;
        self.lane_steps += other.lane_steps;
        self.batched_lane_steps += other.batched_lane_steps;
        self.admission_deferrals += other.admission_deferrals;
        self.pool_peak_slots = self.pool_peak_slots.max(other.pool_peak_slots);
        self.prefill_waves += other.prefill_waves;
        self.prefill_wave_lanes += other.prefill_wave_lanes;
        self.prefill_dispatches += other.prefill_dispatches;
        self.prefill_tokens += other.prefill_tokens;
        self.phase_prefill_seconds += other.phase_prefill_seconds;
        self.accept_depth.merge(&other.accept_depth);
        self.block_draft_sync.merge(&other.block_draft_sync);
        self.block_propose.merge(&other.block_propose);
        self.block_verify.merge(&other.block_verify);
        self.queue_wait_hist.merge(&other.queue_wait_hist);
        self.ttft_hist.merge(&other.ttft_hist);
        self.itl_hist.merge(&other.itl_hist);
    }

    /// Render in Prometheus text exposition format (`GET /metrics`).
    /// Quantiles are emitted as a summary-style family computed over the
    /// retained (windowed) per-request samples.
    pub fn prometheus_text(&self) -> String {
        let mut s = String::new();
        prom_counter(&mut s, "specd_requests_total", "Completed generation requests.",
                     self.total_requests as f64);
        prom_counter(&mut s, "specd_tokens_generated_total", "New tokens emitted.",
                     self.total_new_tokens as f64);
        prom_counter(&mut s, "specd_request_timeouts_total", "Requests evicted past deadline.",
                     self.timeouts as f64);
        prom_counter(&mut s, "specd_requests_cancelled_total",
                     "Streaming clients that disconnected.", self.cancelled as f64);
        prom_counter(&mut s, "specd_spec_blocks_total",
                     "Target verify runs (speculation blocks).", self.spec.blocks as f64);
        prom_counter(&mut s, "specd_spec_drafted_total", "Draft tokens proposed.",
                     self.spec.drafted as f64);
        prom_counter(&mut s, "specd_spec_accepted_total", "Draft tokens accepted.",
                     self.spec.accepted as f64);
        prom_counter(&mut s, "specd_draft_calls_total", "Draft model executions.",
                     self.spec.draft_calls as f64);
        prom_counter(&mut s, "specd_target_calls_total", "Target model executions.",
                     self.spec.target_calls as f64);
        prom_gauge(&mut s, "specd_block_efficiency", "Mean tokens per speculation block (tau).",
                   self.spec.block_efficiency());
        prom_gauge(&mut s, "specd_acceptance_rate", "Draft-token acceptance rate.",
                   self.spec.acceptance_rate());
        // Scheduler-side families, only meaningful when this aggregate came
        // from a coordinator run. The HTTP server's live aggregate is built
        // from per-request responses and never populates them — omitting
        // empty families there avoids misleading always-zero series next to
        // the real `specd_sched_*` gauges.
        if self.batch_iterations > 0 || self.prefill_waves > 0 {
            prom_counter(&mut s, "specd_batch_iterations_total",
                         "Lockstep batch steps executed by the scheduler.",
                         self.batch_iterations as f64);
            prom_counter(&mut s, "specd_phase_draft_sync_seconds_total",
                         "Wall seconds in the draft-sync phase.", self.phase_draft_sync_seconds);
            prom_counter(&mut s, "specd_phase_propose_seconds_total",
                         "Wall seconds in the proposal-round phases.", self.phase_propose_seconds);
            prom_counter(&mut s, "specd_phase_verify_seconds_total",
                         "Wall seconds in the target-verify phase.", self.phase_verify_seconds);
            prom_counter(&mut s, "specd_dispatches_total",
                         "PJRT executable launches issued by the scheduler.",
                         self.dispatches as f64);
            prom_counter(&mut s, "specd_lane_steps_total",
                         "Lane-blocks emitted across batch steps.", self.lane_steps as f64);
            prom_counter(&mut s, "specd_batched_lane_steps_total",
                         "Lane-blocks served by fused batched dispatch.",
                         self.batched_lane_steps as f64);
            prom_gauge(&mut s, "specd_batch_occupancy",
                       "Mean lanes emitting per batch step.", self.batch_occupancy());
            prom_gauge(&mut s, "specd_dispatches_per_step",
                       "Mean PJRT dispatches per batch step.", self.dispatches_per_step());
            prom_counter(&mut s, "specd_admission_deferrals_total",
                         "Iterations with queued work deferred on an exhausted slot pool.",
                         self.admission_deferrals as f64);
            prom_gauge(&mut s, "specd_pool_peak_slots",
                       "High-water mark of live KV pool slots.", self.pool_peak_slots as f64);
            prom_counter(&mut s, "specd_prefill_waves_total",
                         "Fused batched admission waves executed.", self.prefill_waves as f64);
            prom_counter(&mut s, "specd_prefill_wave_lanes_total",
                         "Lanes admitted through fused waves.", self.prefill_wave_lanes as f64);
            prom_counter(&mut s, "specd_prefill_dispatches_total",
                         "PJRT executable launches issued by admission prefill.",
                         self.prefill_dispatches as f64);
            prom_counter(&mut s, "specd_prefill_tokens_total",
                         "Prompt tokens prefilled at admission.", self.prefill_tokens as f64);
            prom_counter(&mut s, "specd_prefill_seconds_total",
                         "Wall seconds in the admission-prefill phase.",
                         self.phase_prefill_seconds);
            prom_gauge(&mut s, "specd_prefill_mean_wave_lanes",
                       "Mean lanes per fused admission wave.", self.mean_wave_lanes());
            prom_histogram(
                &mut s,
                "specd_block_seconds",
                "Per-iteration engine-phase wall seconds.",
                &[
                    ("phase=\"draft_sync\"", &self.block_draft_sync),
                    ("phase=\"propose\"", &self.block_propose),
                    ("phase=\"verify\"", &self.block_verify),
                ],
            );
        }
        prom_histogram(
            &mut s,
            "specd_accept_depth",
            "Accepted draft tokens per speculation block.",
            &[("", &self.accept_depth)],
        );
        prom_histogram(
            &mut s,
            "specd_queue_wait_seconds",
            "Admission-queue wait (enqueue to prefill start), unwindowed.",
            &[("", &self.queue_wait_hist)],
        );
        prom_histogram(
            &mut s,
            "specd_ttft_seconds",
            "Time to first token, unwindowed.",
            &[("", &self.ttft_hist)],
        );
        prom_histogram(
            &mut s,
            "specd_itl_seconds",
            "Inter-token latency (gap between consecutive streamed tokens), unwindowed.",
            &[("", &self.itl_hist)],
        );

        let mut summary = |name: &str, help: &str, stats: &Option<Stats>| {
            s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} summary\n"));
            if let Some(st) = stats {
                for (q, v) in [("0.5", st.p50), ("0.9", st.p90), ("0.99", st.p99)] {
                    s.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
                }
                s.push_str(&format!("{name}_sum {}\n", st.mean * st.n as f64));
                s.push_str(&format!("{name}_count {}\n", st.n));
            } else {
                s.push_str(&format!("{name}_sum 0\n{name}_count 0\n"));
            }
        };
        summary("specd_request_latency_seconds", "End-to-end request latency.",
                &self.latency_stats());
        summary("specd_prefill_queue_wait_seconds",
                "Admission-queue wait (enqueue to prefill start).", &self.queue_wait_stats());
        s
    }

    pub fn report(&self) -> String {
        let lat = self.latency_stats();
        let ttft = self.ttft_stats();
        let wait = self.queue_wait_stats();
        let fmt = |s: &Option<Stats>, f: fn(&Stats) -> f64| {
            s.as_ref().map(|s| format!("{:.1}ms", f(s) * 1e3)).unwrap_or_else(|| "-".into())
        };
        format!(
            "requests={} tokens={} wall={:.2}s throughput={:.1} tok/s ({:.2} req/s)\n\
             latency p50={} p90={} p99={} | ttft p50={} p90={}\n\
             block_efficiency={:.3} acceptance={:.3}\n\
             phases: prefill={:.2}s draft_sync={:.2}s propose={:.2}s verify={:.2}s over {} steps \
             | pool peak={} deferrals={}\n\
             dispatch: {} total ({:.1}/step) occupancy={:.2} fused_lane_steps={}/{}\n\
             admission: waves={} (mean {:.1} lanes) prefill_tokens={} \
             prefill_dispatches={} queue_wait p50={} p90={}",
            self.total_requests,
            self.total_new_tokens,
            self.wall_seconds,
            self.throughput_tok_s(),
            self.requests_per_sec(),
            fmt(&lat, |s| s.p50),
            fmt(&lat, |s| s.p90),
            fmt(&lat, |s| s.p99),
            fmt(&ttft, |s| s.p50),
            fmt(&ttft, |s| s.p90),
            self.spec.block_efficiency(),
            self.spec.acceptance_rate(),
            self.phase_prefill_seconds,
            self.phase_draft_sync_seconds,
            self.phase_propose_seconds,
            self.phase_verify_seconds,
            self.batch_iterations,
            self.pool_peak_slots,
            self.admission_deferrals,
            self.dispatches,
            self.dispatches_per_step(),
            self.batch_occupancy(),
            self.batched_lane_steps,
            self.lane_steps,
            self.prefill_waves,
            self.mean_wave_lanes(),
            self.prefill_tokens,
            self.prefill_dispatches,
            fmt(&wait, |s| s.p50),
            fmt(&wait, |s| s.p90),
        )
    }
}

/// Aggregate for one `specd distill` bulk-generation run. Offline
/// throughput mode: no latencies or deadlines — the numbers that matter
/// are tokens/s of target-verified response tokens, bytes of shards
/// written, and how much wall time the top-k capture path cost (compare a
/// run against `--topk 0` for the marginal overhead).
#[derive(Debug, Default)]
pub struct DistillMetrics {
    /// Records (sequences) written by this run.
    pub sequences: usize,
    /// Response tokens written by this run.
    pub response_tokens: usize,
    /// Records already durable when the run started (resume prefix).
    pub resumed_records: usize,
    /// Shards / bytes written by this run.
    pub shards_written: usize,
    pub shard_bytes: u64,
    pub wall_seconds: f64,
    /// Host seconds spent extracting top-k rows (0 with `--topk 0`).
    pub capture_seconds: f64,
    pub batch_iterations: usize,
    pub phase_draft_sync_seconds: f64,
    pub phase_propose_seconds: f64,
    pub phase_verify_seconds: f64,
    /// PJRT executable launches issued by the run's batch steps.
    pub dispatches: u64,
    /// Lane-blocks emitted across steps (occupancy numerator) and the
    /// fused-dispatch share of them.
    pub lane_steps: usize,
    pub batched_lane_steps: usize,
    pub pool_peak_slots: usize,
    /// Fused admission waves executed, and lanes admitted through them.
    pub prefill_waves: usize,
    pub prefill_wave_lanes: usize,
    /// PJRT launches / prompt tokens / wall seconds spent in admission
    /// prefill (wave and per-seed fallback alike).
    pub prefill_dispatches: u64,
    pub prefill_tokens: usize,
    pub phase_prefill_seconds: f64,
    pub spec: SpecStats,
    /// Accepted drafts per speculation block (0..=γ integer buckets).
    pub accept_depth: Histogram,
}

impl DistillMetrics {
    /// Generation throughput: response tokens per wall second.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.response_tokens as f64 / self.wall_seconds
        }
    }

    /// Fraction of wall time spent in top-k capture.
    pub fn capture_overhead(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.capture_seconds / self.wall_seconds
        }
    }

    /// Mean lanes emitting per batch step (0 with no iterations).
    pub fn batch_occupancy(&self) -> f64 {
        if self.batch_iterations == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batch_iterations as f64
        }
    }

    /// Render in Prometheus text exposition format (`specd_distill_*`
    /// families, disjoint from the serving families).
    pub fn prometheus_text(&self) -> String {
        let mut s = String::new();
        prom_counter(&mut s, "specd_distill_sequences_total",
                     "Distillation records written this run.", self.sequences as f64);
        prom_counter(&mut s, "specd_distill_response_tokens_total",
                     "Response tokens written this run.", self.response_tokens as f64);
        prom_counter(&mut s, "specd_distill_shards_total",
                     "Shards written this run.", self.shards_written as f64);
        prom_counter(&mut s, "specd_distill_shard_bytes_total",
                     "Shard bytes written this run.", self.shard_bytes as f64);
        prom_counter(&mut s, "specd_distill_capture_seconds_total",
                     "Host seconds extracting top-k target logits.", self.capture_seconds);
        prom_counter(&mut s, "specd_distill_batch_iterations_total",
                     "Lockstep batch steps executed.", self.batch_iterations as f64);
        prom_counter(&mut s, "specd_distill_dispatches_total",
                     "PJRT executable launches issued.", self.dispatches as f64);
        prom_counter(&mut s, "specd_distill_lane_steps_total",
                     "Lane-blocks emitted across batch steps.", self.lane_steps as f64);
        prom_counter(&mut s, "specd_distill_batched_lane_steps_total",
                     "Lane-blocks served by fused batched dispatch.",
                     self.batched_lane_steps as f64);
        prom_counter(&mut s, "specd_distill_prefill_waves_total",
                     "Fused batched admission waves executed.", self.prefill_waves as f64);
        prom_counter(&mut s, "specd_distill_prefill_wave_lanes_total",
                     "Lanes admitted through fused waves.", self.prefill_wave_lanes as f64);
        prom_counter(&mut s, "specd_distill_prefill_dispatches_total",
                     "PJRT executable launches issued by admission prefill.",
                     self.prefill_dispatches as f64);
        prom_counter(&mut s, "specd_distill_prefill_tokens_total",
                     "Prompt tokens prefilled at admission.", self.prefill_tokens as f64);
        prom_counter(&mut s, "specd_distill_prefill_seconds_total",
                     "Wall seconds in the admission-prefill phase.", self.phase_prefill_seconds);
        prom_gauge(&mut s, "specd_distill_batch_occupancy",
                   "Mean lanes emitting per batch step.", self.batch_occupancy());
        prom_gauge(&mut s, "specd_distill_tokens_per_sec",
                   "Response-token generation throughput.", self.tokens_per_sec());
        prom_gauge(&mut s, "specd_distill_capture_overhead",
                   "Fraction of wall time spent in top-k capture.", self.capture_overhead());
        prom_histogram(
            &mut s,
            "specd_distill_accept_depth",
            "Accepted draft tokens per speculation block.",
            &[("", &self.accept_depth)],
        );
        s
    }

    pub fn report(&self) -> String {
        format!(
            "distill: sequences={} (+{} resumed) tokens={} wall={:.2}s throughput={:.1} tok/s\n\
             shards={} ({} bytes) capture={:.2}s ({:.1}% of wall)\n\
             block_efficiency={:.3} acceptance={:.3}\n\
             phases: prefill={:.2}s draft_sync={:.2}s propose={:.2}s verify={:.2}s \
             over {} steps | pool peak={}\n\
             dispatch: {} total occupancy={:.2} fused_lane_steps={}/{}\n\
             admission: waves={} ({} lanes) prefill_tokens={} prefill_dispatches={}",
            self.sequences,
            self.resumed_records,
            self.response_tokens,
            self.wall_seconds,
            self.tokens_per_sec(),
            self.shards_written,
            self.shard_bytes,
            self.capture_seconds,
            self.capture_overhead() * 100.0,
            self.spec.block_efficiency(),
            self.spec.acceptance_rate(),
            self.phase_prefill_seconds,
            self.phase_draft_sync_seconds,
            self.phase_propose_seconds,
            self.phase_verify_seconds,
            self.batch_iterations,
            self.pool_peak_slots,
            self.dispatches,
            self.batch_occupancy(),
            self.batched_lane_steps,
            self.lane_steps,
            self.prefill_waves,
            self.prefill_wave_lanes,
            self.prefill_tokens,
            self.prefill_dispatches,
        )
    }
}

/// Live scheduler-side gauges, shared (`Arc`) between the scheduler
/// thread and the HTTP `/metrics` handler so pool occupancy and per-phase
/// timing are scrapeable while the server runs. All `Relaxed` atomics:
/// each value is an independent monitoring signal, not a synchronization
/// point. Family names carry a `specd_sched_` prefix so they never
/// collide with the [`ServeMetrics`] aggregate families in one
/// exposition.
#[derive(Debug, Default)]
pub struct SchedulerGauges {
    /// Live slots in the KV pool (sequences currently resident).
    pub pool_live: AtomicUsize,
    /// Pool capacity (the configured `max_slots`).
    pub pool_max: AtomicUsize,
    /// High-water mark of live slots.
    pub pool_peak: AtomicUsize,
    /// Total valid KV positions across live slots.
    pub resident_tokens: AtomicUsize,
    /// Requests visible in the admission queue at the last iteration.
    pub queue_depth: AtomicUsize,
    phase_draft_sync_us: AtomicU64,
    phase_propose_us: AtomicU64,
    phase_verify_us: AtomicU64,
    iterations: AtomicU64,
    deferrals: AtomicU64,
    /// PJRT executable launches issued across batch steps.
    dispatches: AtomicU64,
    /// Lane-blocks emitted across steps, and the fused-dispatch share.
    lane_steps: AtomicU64,
    batched_lane_steps: AtomicU64,
    /// Lanes that emitted in the most recent step (live occupancy gauge).
    pub last_occupancy: AtomicUsize,
    /// Admission-prefill accounting: fused waves, lanes admitted through
    /// them, PJRT launches, prompt tokens, wall microseconds.
    prefill_waves: AtomicU64,
    prefill_wave_lanes: AtomicU64,
    prefill_dispatches: AtomicU64,
    prefill_tokens: AtomicU64,
    prefill_us: AtomicU64,
    /// Width of the most recently opened wave (live gauge).
    pub last_wave_lanes: AtomicUsize,
}

impl SchedulerGauges {
    /// Fold one batch step's timings/dispatch accounting into the counters.
    pub fn record_iteration(&self, t: &crate::batch::PhaseTimings) {
        self.phase_draft_sync_us.fetch_add((t.draft_sync * 1e6) as u64, Ordering::Relaxed);
        self.phase_propose_us.fetch_add((t.propose * 1e6) as u64, Ordering::Relaxed);
        self.phase_verify_us.fetch_add((t.verify * 1e6) as u64, Ordering::Relaxed);
        self.iterations.fetch_add(1, Ordering::Relaxed);
        self.dispatches.fetch_add(t.dispatches, Ordering::Relaxed);
        self.lane_steps.fetch_add(t.lanes as u64, Ordering::Relaxed);
        self.batched_lane_steps.fetch_add(t.batched_lanes as u64, Ordering::Relaxed);
        self.last_occupancy.store(t.lanes, Ordering::Relaxed);
    }

    /// Count one admission deferred on an exhausted slot pool — this is
    /// the live-endpoint signal the `max_slots` sweep protocol gates on
    /// (the coordinator's own aggregate only surfaces at shutdown).
    pub fn record_deferral(&self) {
        self.deferrals.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one scheduler iteration's admission-phase accounting: waves
    /// opened, lanes admitted through them, prefill dispatches/tokens and
    /// wall seconds spent.
    pub fn record_admission(
        &self,
        waves: u64,
        wave_lanes: u64,
        dispatches: u64,
        tokens: u64,
        seconds: f64,
    ) {
        self.prefill_waves.fetch_add(waves, Ordering::Relaxed);
        self.prefill_wave_lanes.fetch_add(wave_lanes, Ordering::Relaxed);
        self.prefill_dispatches.fetch_add(dispatches, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(tokens, Ordering::Relaxed);
        self.prefill_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        if waves > 0 {
            self.last_wave_lanes.store(wave_lanes as usize, Ordering::Relaxed);
        }
    }

    /// Render the scheduler families in Prometheus text format.
    pub fn prometheus_text(&self) -> String {
        let mut s = String::new();
        prom_gauge(&mut s, "specd_sched_pool_live_slots", "Live KV pool slots.",
                   self.pool_live.load(Ordering::Relaxed) as f64);
        prom_gauge(&mut s, "specd_sched_pool_max_slots", "KV pool capacity (max_slots).",
                   self.pool_max.load(Ordering::Relaxed) as f64);
        prom_gauge(&mut s, "specd_sched_pool_peak_slots", "High-water mark of live slots.",
                   self.pool_peak.load(Ordering::Relaxed) as f64);
        prom_gauge(&mut s, "specd_sched_resident_tokens",
                   "Valid KV positions across live slots.",
                   self.resident_tokens.load(Ordering::Relaxed) as f64);
        prom_gauge(&mut s, "specd_sched_queue_depth",
                   "Admission-queue depth at the last scheduler iteration.",
                   self.queue_depth.load(Ordering::Relaxed) as f64);
        prom_gauge(&mut s, "specd_sched_batch_occupancy",
                   "Lanes that emitted in the most recent batch step.",
                   self.last_occupancy.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_iterations_total", "Lockstep batch steps executed.",
                     self.iterations.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_dispatches_total",
                     "PJRT executable launches issued by the scheduler.",
                     self.dispatches.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_lane_steps_total",
                     "Lane-blocks emitted across batch steps.",
                     self.lane_steps.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_batched_lane_steps_total",
                     "Lane-blocks served by fused batched dispatch.",
                     self.batched_lane_steps.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_admission_deferrals_total",
                     "Iterations with queued work deferred on an exhausted slot pool.",
                     self.deferrals.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_prefill_waves_total",
                     "Fused batched admission waves executed.",
                     self.prefill_waves.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_prefill_wave_lanes_total",
                     "Lanes admitted through fused waves.",
                     self.prefill_wave_lanes.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_prefill_dispatches_total",
                     "PJRT executable launches issued by admission prefill.",
                     self.prefill_dispatches.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_prefill_tokens_total",
                     "Prompt tokens prefilled at admission.",
                     self.prefill_tokens.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_prefill_seconds_total",
                     "Wall seconds in the admission-prefill phase.",
                     self.prefill_us.load(Ordering::Relaxed) as f64 / 1e6);
        prom_gauge(&mut s, "specd_sched_last_wave_lanes",
                   "Width of the most recently opened admission wave.",
                   self.last_wave_lanes.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_sched_phase_draft_sync_seconds_total",
                     "Wall seconds in the draft-sync phase.",
                     self.phase_draft_sync_us.load(Ordering::Relaxed) as f64 / 1e6);
        prom_counter(&mut s, "specd_sched_phase_propose_seconds_total",
                     "Wall seconds in the proposal-round phases.",
                     self.phase_propose_us.load(Ordering::Relaxed) as f64 / 1e6);
        prom_counter(&mut s, "specd_sched_phase_verify_seconds_total",
                     "Wall seconds in the target-verify phase.",
                     self.phase_verify_us.load(Ordering::Relaxed) as f64 / 1e6);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_efficiency_bounds() {
        let s = SpecStats { blocks: 10, generated: 23, ..Default::default() };
        assert!((s.block_efficiency() - 2.3).abs() < 1e-12);
        let empty = SpecStats::default();
        assert_eq!(empty.block_efficiency(), 0.0);
    }

    #[test]
    fn mbsu_paper_example() {
        // Paper headline: tau up to 2.3 at c = 1.64% -> ~2.2x for gamma=3.
        let m = mbsu(2.3, 0.0164, 3);
        assert!(m > 2.1 && m < 2.3, "mbsu={m}");
        // Literal paper formula is two orders smaller — the typo we document.
        assert!(mbsu_paper_literal(2.3, 0.0164, 3) < 0.05);
    }

    #[test]
    fn mbsu_degenerate_cases() {
        // Free draft (c=0): MBSU = tau.
        assert!((mbsu(2.0, 0.0, 5) - 2.0).abs() < 1e-12);
        // tau = 1 with a non-free draft: strictly below 1 (SD loses).
        assert!(mbsu(1.0, 0.5, 4) < 1.0);
    }

    #[test]
    fn rates_and_ratio() {
        let sd = RateMeasurement { new_tokens: 200, elapsed: Duration::from_secs_f64(1.0) };
        let ar = RateMeasurement { new_tokens: 100, elapsed: Duration::from_secs_f64(1.0) };
        assert!((token_rate_ratio(&sd, &ar) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SpecStats { blocks: 1, drafted: 3, accepted: 2, generated: 3,
                                draft_calls: 3, target_calls: 1 };
        let b = a;
        a.merge(&b);
        assert_eq!(a.blocks, 2);
        assert_eq!(a.generated, 6);
        assert!((a.acceptance_rate() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn prometheus_text_renders_counters_and_quantiles() {
        let mut m = ServeMetrics::default();
        m.total_requests = 3;
        m.total_new_tokens = 42;
        m.timeouts = 1;
        m.request_latency = vec![0.1, 0.2, 0.3];
        m.ttft = vec![0.01, 0.02, 0.03];
        m.spec = SpecStats { blocks: 10, generated: 23, drafted: 30, accepted: 20,
                             draft_calls: 30, target_calls: 10 };
        let text = m.prometheus_text();
        assert!(text.contains("specd_requests_total 3"));
        assert!(text.contains("specd_tokens_generated_total 42"));
        assert!(text.contains("specd_request_timeouts_total 1"));
        assert!(text.contains("# TYPE specd_block_efficiency gauge"));
        assert!(text.contains("specd_block_efficiency 2.3"));
        assert!(text.contains("specd_request_latency_seconds{quantile=\"0.5\"} 0.2"));
        assert!(text.contains("specd_request_latency_seconds_count 3"));
        // Exposition format sanity: every non-comment line is `name value`
        // or `name{labels} value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn prometheus_text_empty_metrics_still_valid() {
        let text = ServeMetrics::default().prometheus_text();
        assert!(text.contains("specd_requests_total 0"));
        assert!(text.contains("specd_request_latency_seconds_count 0"));
    }

    #[test]
    fn serve_metrics_merge_accumulates() {
        let mut a = ServeMetrics::default();
        a.total_requests = 1;
        a.request_latency = vec![0.1];
        a.spec.blocks = 2;
        let mut b = ServeMetrics::default();
        b.total_requests = 2;
        b.timeouts = 1;
        b.request_latency = vec![0.2, 0.3];
        b.spec.blocks = 3;
        a.merge(&b);
        assert_eq!(a.total_requests, 3);
        assert_eq!(a.timeouts, 1);
        assert_eq!(a.request_latency.len(), 3);
        assert_eq!(a.spec.blocks, 5);
    }

    #[test]
    fn clip_to_delivered_caps_generated() {
        let mut s = SpecStats { blocks: 4, generated: 10, ..Default::default() };
        assert!((s.block_efficiency() - 2.5).abs() < 1e-12);
        // Overshot block: only 8 tokens were delivered after truncation.
        s.clip_to_delivered(8);
        assert_eq!(s.generated, 8);
        assert!((s.block_efficiency() - 2.0).abs() < 1e-12);
        // Never grows the counter.
        s.clip_to_delivered(100);
        assert_eq!(s.generated, 8);
    }

    #[test]
    fn phase_and_pool_metrics_merge_and_render() {
        let mut a = ServeMetrics::default();
        a.batch_iterations = 2;
        a.phase_draft_sync_seconds = 0.5;
        a.phase_verify_seconds = 1.5;
        a.pool_peak_slots = 3;
        a.admission_deferrals = 1;
        a.dispatches = 20;
        a.lane_steps = 6;
        a.batched_lane_steps = 6;
        a.prefill_waves = 2;
        a.prefill_wave_lanes = 6;
        a.prefill_dispatches = 8;
        a.prefill_tokens = 96;
        a.phase_prefill_seconds = 0.125;
        a.queue_wait = vec![0.01, 0.03];
        let mut b = ServeMetrics::default();
        b.batch_iterations = 1;
        b.phase_draft_sync_seconds = 0.25;
        b.pool_peak_slots = 2;
        b.dispatches = 10;
        b.lane_steps = 3;
        b.prefill_waves = 1;
        b.prefill_wave_lanes = 2;
        b.prefill_dispatches = 4;
        b.prefill_tokens = 32;
        b.phase_prefill_seconds = 0.125;
        b.queue_wait = vec![0.02];
        a.merge(&b);
        assert_eq!(a.batch_iterations, 3);
        assert!((a.phase_draft_sync_seconds - 0.75).abs() < 1e-12);
        assert_eq!(a.pool_peak_slots, 3, "peak merges as max");
        assert_eq!(a.dispatches, 30);
        assert_eq!(a.lane_steps, 9);
        assert_eq!(a.batched_lane_steps, 6);
        assert!((a.batch_occupancy() - 3.0).abs() < 1e-12);
        assert!((a.dispatches_per_step() - 10.0).abs() < 1e-12);
        assert_eq!(a.prefill_waves, 3);
        assert_eq!(a.prefill_wave_lanes, 8);
        assert_eq!(a.prefill_dispatches, 12);
        assert_eq!(a.prefill_tokens, 128);
        assert!((a.phase_prefill_seconds - 0.25).abs() < 1e-12);
        assert_eq!(a.queue_wait.len(), 3, "queue-wait samples merge (windowed)");
        assert!((a.mean_wave_lanes() - 8.0 / 3.0).abs() < 1e-12);
        let text = a.prometheus_text();
        assert!(text.contains("specd_phase_draft_sync_seconds_total 0.75"));
        assert!(text.contains("specd_phase_verify_seconds_total 1.5"));
        assert!(text.contains("specd_batch_iterations_total 3"));
        assert!(text.contains("specd_pool_peak_slots 3"));
        assert!(text.contains("specd_admission_deferrals_total 1"));
        assert!(text.contains("specd_dispatches_total 30"));
        assert!(text.contains("specd_lane_steps_total 9"));
        assert!(text.contains("specd_batched_lane_steps_total 6"));
        assert!(text.contains("specd_batch_occupancy 3"));
        assert!(text.contains("specd_dispatches_per_step 10"));
        assert!(text.contains("specd_prefill_waves_total 3"));
        assert!(text.contains("specd_prefill_wave_lanes_total 8"));
        assert!(text.contains("specd_prefill_dispatches_total 12"));
        assert!(text.contains("specd_prefill_tokens_total 128"));
        assert!(text.contains("specd_prefill_seconds_total 0.25"));
        assert!(text.contains("specd_prefill_queue_wait_seconds{quantile=\"0.5\"} 0.02"));
        let report = a.report();
        assert!(report.contains("pool peak=3"), "report: {report}");
        assert!(report.contains("occupancy=3.00"), "report: {report}");
        assert!(report.contains("fused_lane_steps=6/9"), "report: {report}");
        assert!(report.contains("waves=3 (mean 2.7 lanes)"), "report: {report}");
        assert!(report.contains("prefill_tokens=128"), "report: {report}");
    }

    #[test]
    fn prefill_families_render_without_batch_iterations() {
        // An aggregate that only admitted (no speculation block ran yet)
        // must still expose the admission families.
        let mut m = ServeMetrics::default();
        m.prefill_waves = 1;
        m.prefill_wave_lanes = 4;
        m.prefill_tokens = 64;
        let text = m.prometheus_text();
        assert!(text.contains("specd_prefill_waves_total 1"));
        assert!(text.contains("specd_prefill_mean_wave_lanes 4"));
        // And an empty aggregate (HTTP live view) still omits them.
        let empty = ServeMetrics::default().prometheus_text();
        assert!(!empty.contains("specd_prefill_waves_total"));
        assert!(empty.contains("specd_prefill_queue_wait_seconds_count 0"));
    }

    #[test]
    fn scheduler_gauges_render() {
        let g = SchedulerGauges::default();
        g.pool_live.store(3, Ordering::Relaxed);
        g.pool_max.store(4, Ordering::Relaxed);
        g.pool_peak.store(4, Ordering::Relaxed);
        g.resident_tokens.store(512, Ordering::Relaxed);
        let t1 = crate::batch::PhaseTimings {
            draft_sync: 0.5,
            propose: 1.0,
            verify: 0.25,
            dispatches: 8,
            lanes: 4,
            batched_lanes: 4,
        };
        let t2 = crate::batch::PhaseTimings {
            draft_sync: 0.5,
            propose: 0.0,
            verify: 0.25,
            dispatches: 8,
            lanes: 3,
            batched_lanes: 0,
        };
        g.record_iteration(&t1);
        g.record_iteration(&t2);
        g.record_deferral();
        g.record_admission(1, 3, 6, 64, 0.5);
        g.record_admission(0, 0, 2, 16, 0.25); // wave-less iteration keeps the gauge
        let text = g.prometheus_text();
        assert!(text.contains("specd_sched_pool_live_slots 3"));
        assert!(text.contains("specd_sched_pool_max_slots 4"));
        assert!(text.contains("specd_sched_resident_tokens 512"));
        assert!(text.contains("specd_sched_iterations_total 2"));
        assert!(text.contains("specd_sched_admission_deferrals_total 1"));
        assert!(text.contains("specd_sched_phase_draft_sync_seconds_total 1"));
        assert!(text.contains("specd_sched_phase_verify_seconds_total 0.5"));
        assert!(text.contains("specd_sched_dispatches_total 16"));
        assert!(text.contains("specd_sched_lane_steps_total 7"));
        assert!(text.contains("specd_sched_batched_lane_steps_total 4"));
        assert!(text.contains("specd_sched_batch_occupancy 3"), "last step's occupancy");
        assert!(text.contains("specd_sched_prefill_waves_total 1"));
        assert!(text.contains("specd_sched_prefill_wave_lanes_total 3"));
        assert!(text.contains("specd_sched_prefill_dispatches_total 8"));
        assert!(text.contains("specd_sched_prefill_tokens_total 80"));
        assert!(text.contains("specd_sched_prefill_seconds_total 0.75"));
        assert!(text.contains("specd_sched_last_wave_lanes 3"), "wave-less iterations keep it");
        // Families must not collide with the ServeMetrics exposition.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.starts_with("specd_sched_"), "bad family: {line}");
        }
    }

    #[test]
    fn distill_metrics_rates_and_report() {
        let empty = DistillMetrics::default();
        assert_eq!(empty.tokens_per_sec(), 0.0);
        assert_eq!(empty.capture_overhead(), 0.0);
        let m = DistillMetrics {
            sequences: 4,
            response_tokens: 200,
            wall_seconds: 2.0,
            capture_seconds: 0.5,
            shards_written: 2,
            shard_bytes: 4096,
            spec: SpecStats { blocks: 50, generated: 200, drafted: 150, accepted: 120,
                              draft_calls: 150, target_calls: 50 },
            ..DistillMetrics::default()
        };
        assert!((m.tokens_per_sec() - 100.0).abs() < 1e-9);
        assert!((m.capture_overhead() - 0.25).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("throughput=100.0 tok/s"), "report: {r}");
        assert!(r.contains("shards=2 (4096 bytes)"), "report: {r}");
        assert!(r.contains("capture=0.50s (25.0% of wall)"), "report: {r}");
    }

    #[test]
    fn distill_prometheus_families_are_disjoint() {
        let m = DistillMetrics {
            sequences: 1,
            response_tokens: 10,
            wall_seconds: 1.0,
            ..DistillMetrics::default()
        };
        let text = m.prometheus_text();
        assert!(text.contains("specd_distill_response_tokens_total 10"));
        assert!(text.contains("specd_distill_tokens_per_sec 10"));
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.starts_with("specd_distill_"), "bad family: {line}");
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn histogram_buckets_cumulatively_and_exposes() {
        let mut h = Histogram::with_bounds(&[0.01, 0.1, 1.0]);
        for v in [0.005, 0.01, 0.05, 0.5, 2.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 2.565).abs() < 1e-12);
        let mut s = String::new();
        prom_histogram(&mut s, "t_seconds", "help.", &[("", &h)]);
        assert!(s.contains("# TYPE t_seconds histogram"), "{s}");
        // Cumulative: 0.01 holds both the below-bound and the exact-bound
        // sample (le is inclusive).
        assert!(s.contains("t_seconds_bucket{le=\"0.01\"} 2"), "{s}");
        assert!(s.contains("t_seconds_bucket{le=\"0.1\"} 3"), "{s}");
        assert!(s.contains("t_seconds_bucket{le=\"1\"} 4"), "{s}");
        assert!(s.contains("t_seconds_bucket{le=\"+Inf\"} 5"), "{s}");
        assert!(s.contains("t_seconds_sum 2.565"), "{s}");
        assert!(s.contains("t_seconds_count 5"), "{s}");
        // Exposition invariant: every non-comment line is `name value`.
        for line in s.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn histogram_accept_depth_has_integer_buckets() {
        let gamma = 3;
        let mut h = Histogram::accept_depth(gamma);
        for depth in [0, 1, 1, 3, 3, 3, 2] {
            h.observe(depth as f64);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 13.0, "sum must equal total accepted tokens");
        let mut s = String::new();
        prom_histogram(&mut s, "specd_accept_depth", "help.", &[("", &h)]);
        assert!(s.contains("specd_accept_depth_bucket{le=\"0\"} 1"), "{s}");
        assert!(s.contains("specd_accept_depth_bucket{le=\"1\"} 3"), "{s}");
        assert!(s.contains("specd_accept_depth_bucket{le=\"2\"} 4"), "{s}");
        assert!(s.contains("specd_accept_depth_bucket{le=\"3\"} 7"), "{s}");
        assert!(s.contains("specd_accept_depth_bucket{le=\"+Inf\"} 7"), "{s}");
    }

    #[test]
    fn histogram_merge_adds_and_adopts() {
        let mut a = Histogram::default(); // uninitialized side
        let mut b = Histogram::accept_depth(2);
        b.observe(0.0);
        b.observe(2.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        let mut c = Histogram::accept_depth(2);
        c.observe(1.0);
        a.merge(&c);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum(), 3.0);
        let mut s = String::new();
        prom_histogram(&mut s, "d", "help.", &[("", &a)]);
        assert!(s.contains("d_bucket{le=\"1\"} 2"), "{s}");
        // Mismatched layouts: counts land at their upper bounds, nothing lost.
        let mut other = Histogram::with_bounds(&[0.5]);
        other.observe(0.25);
        other.observe(9.0); // +Inf bucket
        a.merge(&other);
        assert_eq!(a.count(), 5);
        let mut s = String::new();
        prom_histogram(&mut s, "d", "help.", &[("", &a)]);
        assert!(s.contains("d_bucket{le=\"+Inf\"} 5"), "{s}");
    }

    #[test]
    fn histogram_phase_labels_render_one_family() {
        let mut ds = Histogram::with_bounds(&BLOCK_SECONDS_BOUNDS);
        let mut v = Histogram::with_bounds(&BLOCK_SECONDS_BOUNDS);
        ds.observe(0.002);
        v.observe(0.02);
        let mut s = String::new();
        prom_histogram(
            &mut s,
            "specd_block_seconds",
            "help.",
            &[("phase=\"draft_sync\"", &ds), ("phase=\"verify\"", &v)],
        );
        assert_eq!(s.matches("# TYPE specd_block_seconds histogram").count(), 1);
        assert!(s.contains("specd_block_seconds_bucket{phase=\"draft_sync\",le=\"0.0025\"} 1"),
                "{s}");
        assert!(s.contains("specd_block_seconds_bucket{phase=\"verify\",le=\"+Inf\"} 1"), "{s}");
        assert!(s.contains("specd_block_seconds_sum{phase=\"verify\"} 0.02"), "{s}");
        assert!(s.contains("specd_block_seconds_count{phase=\"draft_sync\"} 1"), "{s}");
        // Labels carry no spaces: the 2-field exposition invariant holds.
        for line in s.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn serve_metrics_render_new_histogram_families() {
        let mut m = ServeMetrics::default();
        m.accept_depth = Histogram::accept_depth(3);
        m.accept_depth.observe(2.0);
        m.queue_wait_hist = Histogram::with_bounds(&QUEUE_WAIT_BOUNDS);
        m.queue_wait_hist.observe(0.03);
        m.ttft_hist = Histogram::with_bounds(&TTFT_BOUNDS);
        m.ttft_hist.observe(0.08);
        m.itl_hist = Histogram::with_bounds(&ITL_BOUNDS);
        m.itl_hist.observe(0.004);
        m.batch_iterations = 1;
        m.block_verify = Histogram::with_bounds(&BLOCK_SECONDS_BOUNDS);
        m.block_verify.observe(0.004);
        let text = m.prometheus_text();
        assert!(text.contains("# TYPE specd_accept_depth histogram"), "{text}");
        assert!(text.contains("specd_accept_depth_bucket{le=\"3\"} 1"), "{text}");
        assert!(text.contains("specd_queue_wait_seconds_bucket{le=\"0.05\"} 1"), "{text}");
        assert!(text.contains("specd_block_seconds_bucket{phase=\"verify\",le=\"0.005\"} 1"),
                "{text}");
        // TTFT/ITL are real histograms now (not summaries): quantile
        // state survives scrape resets and merges across instances.
        assert!(text.contains("# TYPE specd_ttft_seconds histogram"), "{text}");
        assert!(text.contains("specd_ttft_seconds_bucket{le=\"0.1\"} 1"), "{text}");
        assert!(text.contains("# TYPE specd_itl_seconds histogram"), "{text}");
        assert!(text.contains("specd_itl_seconds_bucket{le=\"0.005\"} 1"), "{text}");
        assert!(!text.contains("# TYPE specd_ttft_seconds summary"), "{text}");
        // The live HTTP aggregate (no scheduler fields) still renders the
        // request-scoped histograms but not the phase family.
        let empty = ServeMetrics::default().prometheus_text();
        assert!(empty.contains("specd_accept_depth_bucket{le=\"+Inf\"} 0"), "{empty}");
        assert!(empty.contains("specd_queue_wait_seconds_count 0"), "{empty}");
        assert!(empty.contains("specd_ttft_seconds_count 0"), "{empty}");
        assert!(empty.contains("specd_itl_seconds_count 0"), "{empty}");
        assert!(!empty.contains("specd_block_seconds"), "{empty}");
    }

    #[test]
    fn serve_report_renders() {
        let mut m = ServeMetrics::default();
        m.total_requests = 2;
        m.total_new_tokens = 50;
        m.wall_seconds = 1.0;
        m.request_latency = vec![0.1, 0.2];
        m.ttft = vec![0.01, 0.02];
        m.spec = SpecStats { blocks: 10, generated: 20, drafted: 30, accepted: 10,
                             draft_calls: 30, target_calls: 10 };
        let r = m.report();
        assert!(r.contains("throughput=50.0 tok/s"));
        assert!(r.contains("block_efficiency=2.000"));
    }
}

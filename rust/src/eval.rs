//! Figure/table harness: runs (draft model, task, gamma) cells and emits
//! the rows the paper's evaluation reports (Figures 1-3, Table 1).
//!
//! Conventions copied from §3 of the paper:
//! * per-task sampling regimes via [`SamplingConfig::for_task`];
//! * block efficiency is aggregated as total generated / total blocks over
//!   the prompt set (a per-task scalar, like the paper's bar charts);
//! * MBSU uses the *measured* parameter ratio `c` from the manifest;
//! * token-rate ratio compares wall-clock SD vs autoregressive decoding on
//!   the same prompts/sampler (the AR baseline is cached per task since it
//!   is draft-independent).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::baseline::ArDecoder;
use crate::config::SamplingConfig;
use crate::error::Result;
use crate::metrics::{mbsu, RateMeasurement, SpecStats};
use crate::rng::Pcg64;
use crate::runtime::Model;
use crate::spec::SpecDecoder;
use crate::workload::EvalSuite;

/// One cell of a figure.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub draft_model: String,
    pub task: String,
    pub gamma: usize,
    pub n_prompts: usize,
    pub tau: f64,
    pub acceptance: f64,
    pub mbsu: f64,
    pub sd_tok_s: f64,
    pub ar_tok_s: f64,
    pub rate_ratio: f64,
    pub stats: SpecStats,
}

/// Evaluation options.
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    pub n_prompts: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { n_prompts: 16, max_new: 32, seed: 0 }
    }
}

/// Cached autoregressive baselines keyed by (task, n_prompts, max_new,
/// seed). The seed is part of the key: the per-prompt sampler seeds derive
/// from `EvalOptions::seed`, so a rerun with a different seed is a
/// different measurement — omitting it silently reused a stale baseline.
#[derive(Default)]
pub struct ArBaselineCache {
    cache: BTreeMap<(String, usize, usize, u64), RateMeasurement>,
}

impl ArBaselineCache {
    fn key(task: &str, opts: &EvalOptions) -> (String, usize, usize, u64) {
        (task.to_string(), opts.n_prompts, opts.max_new, opts.seed)
    }

    /// Cached measurement for this (task, options) cell, if any.
    pub fn get(&self, task: &str, opts: &EvalOptions) -> Option<RateMeasurement> {
        self.cache.get(&Self::key(task, opts)).copied()
    }

    /// Record a measurement for this cell.
    pub fn insert(&mut self, task: &str, opts: &EvalOptions, m: RateMeasurement) {
        self.cache.insert(Self::key(task, opts), m);
    }

    pub fn get_or_run(
        &mut self,
        target: &Model,
        suite: &EvalSuite,
        task: &str,
        opts: &EvalOptions,
    ) -> Result<RateMeasurement> {
        if let Some(m) = self.get(task, opts) {
            return Ok(m);
        }
        let decoder = ArDecoder::new(target);
        let examples = suite.take(task, opts.n_prompts)?;
        let mut tokens = 0usize;
        let mut elapsed = std::time::Duration::ZERO;
        for (i, ex) in examples.iter().enumerate() {
            let cfg = SamplingConfig::for_task(task, opts.seed + i as u64);
            let mut rng = Pcg64::with_stream(cfg.seed, 0xba5e);
            let (out, _stats, rate) = decoder.generate(&ex.prompt, opts.max_new, &cfg, &mut rng)?;
            tokens += out.len();
            elapsed += rate.elapsed;
        }
        let m = RateMeasurement { new_tokens: tokens, elapsed };
        self.insert(task, opts, m);
        Ok(m)
    }
}

/// Run one (draft, task, gamma) cell: SD over the prompt set + cached AR.
pub fn eval_cell(
    draft: &Model,
    target: &Model,
    suite: &EvalSuite,
    task: &str,
    gamma: usize,
    opts: &EvalOptions,
    ar_cache: &mut ArBaselineCache,
) -> Result<CellResult> {
    let decoder = SpecDecoder::new(draft, target, gamma)?;
    let examples = suite.take(task, opts.n_prompts)?;
    let mut stats = SpecStats::default();
    let mut sd_tokens = 0usize;
    let t0 = Instant::now();
    for (i, ex) in examples.iter().enumerate() {
        // Same per-prompt sampler seeds as the AR baseline: the comparison
        // isolates the decoding strategy.
        let cfg = SamplingConfig::for_task(task, opts.seed + i as u64);
        let mut rng = Pcg64::with_stream(cfg.seed, 0x5bec);
        let (out, s) = decoder.generate(&ex.prompt, opts.max_new, &cfg, &mut rng)?;
        sd_tokens += out.len();
        stats.merge(&s);
    }
    let sd_rate = RateMeasurement { new_tokens: sd_tokens, elapsed: t0.elapsed() };
    let ar_rate = ar_cache.get_or_run(target, suite, task, opts)?;

    let tau = stats.block_efficiency();
    Ok(CellResult {
        draft_model: draft.name.clone(),
        task: task.to_string(),
        gamma,
        n_prompts: examples.len(),
        tau,
        acceptance: stats.acceptance_rate(),
        mbsu: mbsu(tau, draft.c_ratio, gamma),
        sd_tok_s: sd_rate.tokens_per_sec(),
        ar_tok_s: ar_rate.tokens_per_sec(),
        rate_ratio: crate::metrics::token_rate_ratio(&sd_rate, &ar_rate),
        stats,
    })
}

/// Block-efficiency-only cell (Figure 2/3 sweeps — no AR timing needed).
pub fn eval_block_efficiency(
    draft: &Model,
    target: &Model,
    suite: &EvalSuite,
    task: &str,
    gamma: usize,
    opts: &EvalOptions,
) -> Result<CellResult> {
    let decoder = SpecDecoder::new(draft, target, gamma)?;
    let examples = suite.take(task, opts.n_prompts)?;
    let mut stats = SpecStats::default();
    for (i, ex) in examples.iter().enumerate() {
        let cfg = SamplingConfig::for_task(task, opts.seed + i as u64);
        let mut rng = Pcg64::with_stream(cfg.seed, 0x5bec);
        let (_out, s) = decoder.generate(&ex.prompt, opts.max_new, &cfg, &mut rng)?;
        stats.merge(&s);
    }
    let tau = stats.block_efficiency();
    Ok(CellResult {
        draft_model: draft.name.clone(),
        task: task.to_string(),
        gamma,
        n_prompts: examples.len(),
        tau,
        acceptance: stats.acceptance_rate(),
        mbsu: mbsu(tau, draft.c_ratio, gamma),
        sd_tok_s: 0.0,
        ar_tok_s: 0.0,
        rate_ratio: 0.0,
        stats,
    })
}

/// Render cells as a figure table (one row per cell).
pub fn render_cells(title: &str, cells: &[CellResult], with_rates: bool) {
    println!("\n=== {title} ===");
    let mut headers = vec!["draft", "task", "gamma", "tau", "accept", "MBSU"];
    if with_rates {
        headers.extend_from_slice(&["SD tok/s", "AR tok/s", "ratio"]);
    }
    let mut table = crate::benchkit::Table::new(&headers);
    for c in cells {
        let mut row = vec![
            c.draft_model.clone(),
            c.task.clone(),
            c.gamma.to_string(),
            format!("{:.3}", c.tau),
            format!("{:.3}", c.acceptance),
            format!("{:.3}", c.mbsu),
        ];
        if with_rates {
            row.push(format!("{:.1}", c.sd_tok_s));
            row.push(format!("{:.1}", c.ar_tok_s));
            row.push(format!("{:.2}", c.rate_ratio));
        }
        table.row(&row);
    }
    table.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_sane() {
        let o = EvalOptions::default();
        assert!(o.n_prompts > 0 && o.max_new > 0);
    }

    /// Regression: the AR baseline cache must key on the eval seed — the
    /// old (task, n_prompts, max_new) key silently reused a stale baseline
    /// when only the seed changed.
    #[test]
    fn ar_cache_distinguishes_seeds() {
        let mut cache = ArBaselineCache::default();
        let seed0 = EvalOptions { seed: 0, ..EvalOptions::default() };
        let seed1 = EvalOptions { seed: 1, ..EvalOptions::default() };
        let m = RateMeasurement {
            new_tokens: 100,
            elapsed: std::time::Duration::from_secs(1),
        };
        cache.insert("dolly", &seed0, m);
        assert!(cache.get("dolly", &seed0).is_some(), "same seed hits");
        assert!(cache.get("dolly", &seed1).is_none(), "different seed must re-measure");
        assert!(cache.get("xsum", &seed0).is_none(), "different task must re-measure");
        let other = EvalOptions { n_prompts: seed0.n_prompts + 1, ..seed0 };
        assert!(cache.get("dolly", &other).is_none());
        assert_eq!(cache.get("dolly", &seed0).unwrap().new_tokens, 100);
    }
}

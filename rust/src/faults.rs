//! Fault-domain layer: deterministic fault injection, transient-error
//! retry with bounded exponential backoff, and per-model circuit
//! breakers.
//!
//! Speculative decoding is a lossless accelerator — the target verify
//! pass is ground truth — so a draft-side failure should cost throughput,
//! never availability or correctness. This module gives the serving stack
//! the machinery to hold that line:
//!
//!   * [`FaultPlan`]: a seeded, deterministic injection plan armed from
//!     the CLI (`--fault-plan "seed=7;dispatch:run_lanes:every=97"`).
//!     Injection sites in the runtime dispatch paths, the exec channel,
//!     and dataset IO call [`inject`], which is one relaxed atomic load
//!     when no plan is armed — the same disabled-path discipline as
//!     trace/telemetry.
//!   * [`dispatch`]: wraps a fallible dispatch closure in a bounded
//!     exponential-backoff retry loop. Only errors classified transient
//!     by [`Error::is_transient`] are retried; the attempt budget and
//!     backoff schedule are fixed so a permanently failing backend fails
//!     fast.
//!   * [`Breaker`]: a closed → open → half-open circuit breaker, one per
//!     model. The engine consults the *draft* breaker to drop into
//!     target-only (γ=0) decoding while the draft backend is unhealthy,
//!     and probes back to speculation through the half-open state.
//!
//! Grammar for `--fault-plan` (rules separated by `;` or `,`):
//!
//! ```text
//! seed=N                          plan-wide RNG seed (default 0)
//! <domain>:<op>:<mode>[:burst=K][:permanent]
//!   domain:op  dispatch:run_lanes | dispatch:run_into |
//!              dispatch:pack_lane | exec:send | io:read | io:write |
//!              swap:stage | swap:readmit
//!   mode       every=N   fire on every Nth passage of the site
//!              after=N   fire once at the Nth passage
//!              p=F       fire with probability F (per-rule rng.rs stream)
//!   burst=K    each trigger fires on K consecutive passages (default 1;
//!              use K > the retry budget to defeat retries and trip the
//!              breaker)
//!   permanent  injected errors are permanent (not retried); default
//!              transient
//! ```
//!
//! All counters are process-global atomics surfaced as the
//! `specd_faults_injected_total` / `specd_dispatch_retries_total` /
//! `specd_lanes_salvaged_total` / `specd_breaker_state` /
//! `specd_degraded_mode` Prometheus families via
//! [`Resilience::prometheus_text`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

#[cfg(loom)]
use loom::sync::atomic::{AtomicU64 as BreakerAtomicU64, Ordering as BreakerOrdering};
#[cfg(not(loom))]
use std::sync::atomic::{AtomicU64 as BreakerAtomicU64, Ordering as BreakerOrdering};

use crate::error::{Error, Result};
use crate::metrics::{prom_counter, prom_gauge};
use crate::rng::Pcg64;
use crate::trace;

// ---- injection sites ------------------------------------------------------

/// One instrumented failure point. The numeric value is the `a` field of
/// the corresponding trace instants.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Site {
    /// Fused batched decode dispatch (`runtime::Model::run_lanes`).
    RunLanes = 0,
    /// Per-lane decode/prefill dispatch (`runtime::Model::run_into`).
    RunInto = 1,
    /// Lane compaction dispatch (`runtime::Model::pack_lane`).
    PackLane = 2,
    /// Bounded-channel send in `exec` (scheduler intake path).
    ExecSend = 3,
    /// Dataset shard/manifest read.
    IoRead = 4,
    /// Dataset shard/manifest write.
    IoWrite = 5,
    /// Draft-lifecycle: staged candidate-bundle load + validation
    /// (`runtime::stage_draft`). A hit rejects the reload; serving is
    /// untouched.
    SwapStage = 6,
    /// Draft-lifecycle: resident-lane re-admission after a swap or a
    /// supervisor restart (`coordinator` resume path). A hit exercises
    /// the salvage-style retry, then the stranded-request terminal.
    SwapReadmit = 7,
}

impl Site {
    /// `domain:op` spelling used by the plan grammar and trace export.
    pub fn name(self) -> &'static str {
        match self {
            Site::RunLanes => "dispatch:run_lanes",
            Site::RunInto => "dispatch:run_into",
            Site::PackLane => "dispatch:pack_lane",
            Site::ExecSend => "exec:send",
            Site::IoRead => "io:read",
            Site::IoWrite => "io:write",
            Site::SwapStage => "swap:stage",
            Site::SwapReadmit => "swap:readmit",
        }
    }

    /// Reverse of the trace `a` field encoding; `None` for out-of-range.
    pub fn from_index(i: u64) -> Option<Site> {
        match i {
            0 => Some(Site::RunLanes),
            1 => Some(Site::RunInto),
            2 => Some(Site::PackLane),
            3 => Some(Site::ExecSend),
            4 => Some(Site::IoRead),
            5 => Some(Site::IoWrite),
            6 => Some(Site::SwapStage),
            7 => Some(Site::SwapReadmit),
            _ => None,
        }
    }

    fn parse(domain: &str, op: &str) -> Option<Site> {
        match (domain, op) {
            ("dispatch", "run_lanes") => Some(Site::RunLanes),
            ("dispatch", "run_into") => Some(Site::RunInto),
            ("dispatch", "pack_lane") => Some(Site::PackLane),
            ("exec", "send") => Some(Site::ExecSend),
            ("io", "read") => Some(Site::IoRead),
            ("io", "write") => Some(Site::IoWrite),
            ("swap", "stage") => Some(Site::SwapStage),
            ("swap", "readmit") => Some(Site::SwapReadmit),
            _ => None,
        }
    }
}

// ---- fault plan -----------------------------------------------------------

#[derive(Clone, Copy, Debug)]
enum Mode {
    /// Fire on every Nth passage of the site.
    Every(u64),
    /// Fire once, at the Nth passage.
    After(u64),
    /// Fire with probability `p` per passage (deterministic per-rule
    /// rng stream, so a seeded plan replays identically).
    Prob(f64),
}

#[derive(Debug)]
struct Rule {
    site: Site,
    mode: Mode,
    /// Consecutive passages that fail per trigger (default 1).
    burst: u32,
    transient: bool,
    /// Passages of `site` seen by this rule.
    hits: u64,
    /// Remaining forced failures from an active burst.
    remaining: u32,
    rng: Pcg64,
}

impl Rule {
    /// Advance this rule past one site passage; true means inject now.
    fn fire(&mut self) -> bool {
        self.hits += 1;
        if self.remaining > 0 {
            self.remaining -= 1;
            return true;
        }
        let trigger = match self.mode {
            Mode::Every(n) => n > 0 && self.hits % n == 0,
            Mode::After(n) => self.hits == n,
            Mode::Prob(p) => self.rng.next_f64() < p,
        };
        if trigger {
            self.remaining = self.burst.saturating_sub(1);
        }
        trigger
    }
}

/// A parsed, seeded fault-injection plan. Deterministic: the same spec
/// string replays the same fault sequence at the same site passages.
#[derive(Debug)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    /// The seed the plan was parsed with (spec `seed=N`).
    pub seed: u64,
}

impl FaultPlan {
    /// Parse a plan from the `--fault-plan` grammar (module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 0u64;
        let mut raw_rules: Vec<&str> = Vec::new();
        for tok in spec.split([';', ',']).map(str::trim).filter(|t| !t.is_empty()) {
            if let Some(v) = tok.strip_prefix("seed=") {
                seed = v
                    .parse()
                    .map_err(|_| Error::Cli(format!("fault-plan: bad seed '{v}'")))?;
            } else {
                raw_rules.push(tok);
            }
        }
        let mut rules = Vec::with_capacity(raw_rules.len());
        for (i, tok) in raw_rules.iter().enumerate() {
            rules.push(Self::parse_rule(tok, seed, i as u64)?);
        }
        if rules.is_empty() {
            return Err(Error::Cli(format!("fault-plan: no rules in '{spec}'")));
        }
        Ok(FaultPlan { rules, seed })
    }

    fn parse_rule(tok: &str, seed: u64, index: u64) -> Result<Rule> {
        let bad = |why: &str| Error::Cli(format!("fault-plan rule '{tok}': {why}"));
        let parts: Vec<&str> = tok.split(':').collect();
        if parts.len() < 3 {
            return Err(bad("want domain:op:mode[:burst=K][:permanent]"));
        }
        let site = Site::parse(parts[0], parts[1])
            .ok_or_else(|| bad("unknown site (see --help for the list)"))?;
        let mode = if let Some(v) = parts[2].strip_prefix("every=") {
            Mode::Every(v.parse().map_err(|_| bad("bad every=N"))?)
        } else if let Some(v) = parts[2].strip_prefix("after=") {
            Mode::After(v.parse().map_err(|_| bad("bad after=N"))?)
        } else if let Some(v) = parts[2].strip_prefix("p=") {
            let p: f64 = v.parse().map_err(|_| bad("bad p=F"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(bad("p must be in [0,1]"));
            }
            Mode::Prob(p)
        } else {
            return Err(bad("mode must be every=N, after=N or p=F"));
        };
        let mut burst = 1u32;
        let mut transient = true;
        for extra in &parts[3..] {
            if let Some(v) = extra.strip_prefix("burst=") {
                burst = v.parse().map_err(|_| bad("bad burst=K"))?;
                if burst == 0 {
                    return Err(bad("burst must be >= 1"));
                }
            } else if *extra == "permanent" {
                transient = false;
            } else {
                return Err(bad("unknown modifier"));
            }
        }
        Ok(Rule {
            site,
            mode,
            burst,
            transient,
            hits: 0,
            remaining: 0,
            rng: Pcg64::with_stream(seed, 0xfa17 ^ index),
        })
    }
}

// ---- global plan state ----------------------------------------------------

/// Fast-path flag: one relaxed load decides "no plan armed" without
/// touching the plan mutex (trace/telemetry discipline).
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Process-global observability counters (monotonic; tests take deltas).
static INJECTED: AtomicU64 = AtomicU64::new(0);
static RETRIES: AtomicU64 = AtomicU64::new(0);
static SALVAGED: AtomicU64 = AtomicU64::new(0);

fn plan_lock() -> MutexGuard<'static, Option<FaultPlan>> {
    match PLAN.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Arm a plan process-wide. Replaces any previously armed plan.
pub fn arm(plan: FaultPlan) {
    *plan_lock() = Some(plan);
    ARMED.store(true, Ordering::Release);
}

/// Parse `spec` and arm the resulting plan.
pub fn arm_from_spec(spec: &str) -> Result<()> {
    arm(FaultPlan::parse(spec)?);
    Ok(())
}

/// Disarm injection; [`inject`] reverts to the one-load fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *plan_lock() = None;
}

/// True while a plan is armed (one relaxed load).
#[inline]
pub fn enabled() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// The injection probe. Call at each instrumented site, before the real
/// operation; returns `Err(Error::Fault { .. })` when the armed plan says
/// this passage fails. Disabled cost: one relaxed atomic load.
#[inline]
pub fn inject(site: Site) -> Result<()> {
    if !enabled() {
        return Ok(());
    }
    inject_slow(site)
}

#[cold]
fn inject_slow(site: Site) -> Result<()> {
    let mut guard = plan_lock();
    let Some(plan) = guard.as_mut() else { return Ok(()) };
    for rule in plan.rules.iter_mut().filter(|r| r.site == site) {
        if rule.fire() {
            let transient = rule.transient;
            drop(guard);
            INJECTED.fetch_add(1, Ordering::Relaxed);
            trace::fault(site as u64, transient);
            return Err(Error::Fault { transient, msg: site.name().into() });
        }
    }
    Ok(())
}

/// Lifetime injected-fault count (`specd_faults_injected_total`).
pub fn injected() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Lifetime dispatch-retry count (`specd_dispatch_retries_total`).
pub fn retries() -> u64 {
    RETRIES.load(Ordering::Relaxed)
}

/// Lifetime salvaged-lane count (`specd_lanes_salvaged_total`).
pub fn salvaged() -> u64 {
    SALVAGED.load(Ordering::Relaxed)
}

/// Record `n` lanes re-prefilled back to life after a suspect fused
/// dispatch (called by the coordinator's salvage path).
pub fn add_salvaged(n: u64) {
    SALVAGED.fetch_add(n, Ordering::Relaxed);
}

// ---- retry wrapper --------------------------------------------------------

/// Attempt budget for one logical dispatch (1 initial + 3 retries).
pub const RETRY_ATTEMPTS: u32 = 4;
/// First backoff step; doubles per retry (1ms, 2ms, 4ms).
const RETRY_BASE: Duration = Duration::from_millis(1);

/// Run `f` with bounded exponential-backoff retry on transient errors,
/// recording the outcome of the *logical* call (not each attempt) on
/// `breaker` when one is attached.
///
/// Permanent errors ([`Error::is_transient`] false) and budget exhaustion
/// propagate to the caller after a single failure record.
pub fn dispatch<T>(
    site: Site,
    breaker: Option<&Breaker>,
    mut f: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut attempt = 0u32;
    loop {
        match f() {
            Ok(v) => {
                if let Some(b) = breaker {
                    b.record_success();
                }
                return Ok(v);
            }
            Err(e) => {
                attempt += 1;
                if attempt >= RETRY_ATTEMPTS || !e.is_transient() {
                    if let Some(b) = breaker {
                        b.record_failure();
                    }
                    return Err(e);
                }
                RETRIES.fetch_add(1, Ordering::Relaxed);
                trace::retry(site as u64, attempt as u64);
                std::thread::sleep(RETRY_BASE * (1 << (attempt - 1)));
            }
        }
    }
}

// ---- circuit breaker ------------------------------------------------------

/// Breaker states; the numeric value is the `specd_breaker_state` gauge
/// sample and the trace `b` field.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BreakerState {
    Closed = 0,
    Open = 1,
    HalfOpen = 2,
}

/// Per-model circuit breaker: `threshold` consecutive failed logical
/// dispatches open the circuit; after `cooldown` one probe is granted
/// (half-open); a probe success closes, a probe failure reopens.
///
/// Lock-free (CAS on a single state word) so [`dispatch`] can record
/// outcomes from the scheduler hot path, and loom-aliasable so the state
/// machine is checkable under `--cfg loom`.
pub struct Breaker {
    /// 0 closed / 1 open / 2 half-open.
    state: BreakerAtomicU64,
    /// Consecutive logical-dispatch failures while closed.
    failures: BreakerAtomicU64,
    /// Microseconds since `epoch` when the circuit last opened.
    opened_at_us: BreakerAtomicU64,
    /// Completed open → half-open → closed recovery cycles.
    cycles: BreakerAtomicU64,
    /// Times the circuit opened (first open and half-open reopens).
    opens: BreakerAtomicU64,
    threshold: u64,
    cooldown: Duration,
    epoch: Instant,
    name: &'static str,
    /// Trace `a` field (0 draft, 1 target).
    id: u64,
}

impl Breaker {
    pub fn new(name: &'static str, id: u64, threshold: u32, cooldown: Duration) -> Breaker {
        Breaker {
            state: BreakerAtomicU64::new(BreakerState::Closed as u64),
            failures: BreakerAtomicU64::new(0),
            opened_at_us: BreakerAtomicU64::new(0),
            cycles: BreakerAtomicU64::new(0),
            opens: BreakerAtomicU64::new(0),
            threshold: threshold.max(1) as u64,
            cooldown,
            epoch: Instant::now(),
            name,
            id,
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn state(&self) -> BreakerState {
        match self.state.load(BreakerOrdering::Acquire) {
            0 => BreakerState::Closed,
            1 => BreakerState::Open,
            _ => BreakerState::HalfOpen,
        }
    }

    /// Completed open → half-open → closed recovery cycles.
    pub fn cycles(&self) -> u64 {
        self.cycles.load(BreakerOrdering::Relaxed)
    }

    /// Times the circuit has opened (including half-open reopens).
    pub fn opens(&self) -> u64 {
        self.opens.load(BreakerOrdering::Relaxed)
    }

    /// May the caller attempt a dispatch through this circuit?
    ///
    /// Closed: always. Open: false until `cooldown` has elapsed, then the
    /// first caller to win the open → half-open CAS is granted the single
    /// probe. Half-open: false (a probe is already in flight).
    pub fn allow(&self) -> bool {
        match self.state() {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => false,
            BreakerState::Open => {
                let now_us = self.epoch.elapsed().as_micros() as u64;
                let opened = self.opened_at_us.load(BreakerOrdering::Acquire);
                if now_us.saturating_sub(opened) < self.cooldown.as_micros() as u64 {
                    return false;
                }
                let won = self
                    .state
                    .compare_exchange(
                        BreakerState::Open as u64,
                        BreakerState::HalfOpen as u64,
                        BreakerOrdering::AcqRel,
                        BreakerOrdering::Acquire,
                    )
                    .is_ok();
                if won {
                    trace::breaker(self.id, BreakerState::HalfOpen as u64);
                }
                won
            }
        }
    }

    /// Record a successful logical dispatch. Closes the circuit from
    /// half-open (completing a recovery cycle) and clears the consecutive
    /// failure streak. A success observed while the circuit is still open
    /// also closes it: not every caller consults [`Breaker::allow`] (the
    /// target path dispatches unconditionally), and a completed dispatch
    /// is direct evidence the backend is healthy again — it just does not
    /// count as a probe-driven recovery cycle.
    pub fn record_success(&self) {
        if self
            .state
            .compare_exchange(
                BreakerState::HalfOpen as u64,
                BreakerState::Closed as u64,
                BreakerOrdering::AcqRel,
                BreakerOrdering::Acquire,
            )
            .is_ok()
        {
            self.cycles.fetch_add(1, BreakerOrdering::AcqRel);
            trace::breaker(self.id, BreakerState::Closed as u64);
        } else if self
            .state
            .compare_exchange(
                BreakerState::Open as u64,
                BreakerState::Closed as u64,
                BreakerOrdering::AcqRel,
                BreakerOrdering::Acquire,
            )
            .is_ok()
        {
            trace::breaker(self.id, BreakerState::Closed as u64);
        }
        self.failures.store(0, BreakerOrdering::Release);
    }

    /// Record a failed logical dispatch (post-retry). A half-open probe
    /// failure reopens immediately; while closed, `threshold` consecutive
    /// failures open the circuit.
    pub fn record_failure(&self) {
        if self
            .state
            .compare_exchange(
                BreakerState::HalfOpen as u64,
                BreakerState::Open as u64,
                BreakerOrdering::AcqRel,
                BreakerOrdering::Acquire,
            )
            .is_ok()
        {
            self.reopened();
            return;
        }
        let streak = self.failures.fetch_add(1, BreakerOrdering::AcqRel) + 1;
        if streak >= self.threshold
            && self
                .state
                .compare_exchange(
                    BreakerState::Closed as u64,
                    BreakerState::Open as u64,
                    BreakerOrdering::AcqRel,
                    BreakerOrdering::Acquire,
                )
                .is_ok()
        {
            self.reopened();
        }
    }

    fn reopened(&self) {
        self.opened_at_us
            .store(self.epoch.elapsed().as_micros() as u64, BreakerOrdering::Release);
        self.opens.fetch_add(1, BreakerOrdering::AcqRel);
        trace::breaker(self.id, BreakerState::Open as u64);
    }
}

// ---- resilience bundle ----------------------------------------------------

/// Default consecutive-failure threshold before a circuit opens.
pub const DEFAULT_BREAKER_THRESHOLD: u32 = 3;
/// Default open-circuit cooldown before a half-open probe is granted.
pub const DEFAULT_BREAKER_COOLDOWN: Duration = Duration::from_millis(1000);

/// The per-model breakers for one serving/decoding process, shared
/// between the scheduler thread (records outcomes, consults the draft
/// circuit for degraded mode) and the HTTP server (renders gauges).
pub struct Resilience {
    pub draft: Arc<Breaker>,
    pub target: Arc<Breaker>,
}

impl Resilience {
    pub fn new(threshold: u32, cooldown: Duration) -> Resilience {
        Resilience {
            draft: Arc::new(Breaker::new("draft", 0, threshold, cooldown)),
            target: Arc::new(Breaker::new("target", 1, threshold, cooldown)),
        }
    }

    /// True while the engine is in target-only degraded mode (draft
    /// circuit not closed).
    pub fn degraded(&self) -> bool {
        self.draft.state() != BreakerState::Closed
    }

    /// Render the fault/resilience Prometheus families.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        prom_counter(
            &mut out,
            "specd_faults_injected_total",
            "Faults injected by the armed fault plan.",
            injected() as f64,
        );
        prom_counter(
            &mut out,
            "specd_dispatch_retries_total",
            "Transient dispatch failures absorbed by backoff retry.",
            retries() as f64,
        );
        prom_counter(
            &mut out,
            "specd_lanes_salvaged_total",
            "Lanes re-prefilled back to life after a suspect fused dispatch.",
            salvaged() as f64,
        );
        let fam = "specd_breaker_state";
        out.push_str(&format!(
            "# HELP {fam} Circuit state per model (0 closed, 1 open, 2 half-open).\n\
             # TYPE {fam} gauge\n"
        ));
        for b in [&self.draft, &self.target] {
            out.push_str(&format!("{fam}{{model=\"{}\"}} {}\n", b.name(), b.state() as u64));
        }
        prom_gauge(
            &mut out,
            "specd_degraded_mode",
            "1 while serving target-only (draft circuit not closed).",
            u64::from(self.degraded()) as f64,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Plan state is process-global; tests that arm plans serialize here.
    static PLAN_TEST_LOCK: Mutex<()> = Mutex::new(());

    fn with_plan<T>(spec: &str, f: impl FnOnce() -> T) -> T {
        let _g = PLAN_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        arm_from_spec(spec).unwrap();
        let out = f();
        disarm();
        out
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("seed=1").is_err());
        assert!(FaultPlan::parse("dispatch:run_lanes").is_err());
        assert!(FaultPlan::parse("dispatch:run_lanes:sometimes").is_err());
        assert!(FaultPlan::parse("nope:run_lanes:every=2").is_err());
        assert!(FaultPlan::parse("dispatch:run_lanes:p=1.5").is_err());
        assert!(FaultPlan::parse("dispatch:run_lanes:every=2:burst=0").is_err());
        assert!(FaultPlan::parse("dispatch:run_lanes:every=2:wat").is_err());
    }

    #[test]
    fn parse_accepts_full_grammar() {
        let p = FaultPlan::parse(
            "seed=9; dispatch:run_lanes:every=97, exec:send:after=500;\
             io:read:p=0.25:burst=2:permanent",
        )
        .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[2].burst, 2);
        assert!(!p.rules[2].transient);
    }

    #[test]
    fn every_mode_fires_deterministically() {
        with_plan("dispatch:run_lanes:every=3", || {
            let fired: Vec<bool> =
                (0..9).map(|_| inject(Site::RunLanes).is_err()).collect();
            assert_eq!(
                fired,
                [false, false, true, false, false, true, false, false, true]
            );
            // Other sites unaffected.
            assert!(inject(Site::RunInto).is_ok());
        });
    }

    #[test]
    fn after_mode_fires_once_with_burst() {
        with_plan("exec:send:after=2:burst=3", || {
            let fired: Vec<bool> =
                (0..7).map(|_| inject(Site::ExecSend).is_err()).collect();
            assert_eq!(fired, [false, true, true, true, false, false, false]);
        });
    }

    #[test]
    fn prob_mode_is_seed_deterministic() {
        let run = || {
            with_plan("seed=42;io:write:p=0.5", || {
                (0..32).map(|_| inject(Site::IoWrite).is_err()).collect::<Vec<_>>()
            })
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b);
        assert!(a.iter().any(|&f| f), "p=0.5 over 32 draws should fire");
        assert!(!a.iter().all(|&f| f));
    }

    #[test]
    fn permanent_modifier_reaches_error() {
        with_plan("io:read:after=1:permanent", || {
            let e = inject(Site::IoRead).unwrap_err();
            assert!(!e.is_transient());
        });
    }

    #[test]
    fn disarmed_is_silent() {
        let _g = PLAN_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        disarm();
        assert!(!enabled());
        for _ in 0..100 {
            assert!(inject(Site::RunLanes).is_ok());
        }
    }

    #[test]
    fn dispatch_retries_transient_then_succeeds() {
        let mut calls = 0;
        let out = dispatch(Site::RunLanes, None, || {
            calls += 1;
            if calls < 3 {
                Err(Error::Fault { transient: true, msg: "flaky".into() })
            } else {
                Ok(7)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls, 3);
    }

    #[test]
    fn dispatch_fails_fast_on_permanent() {
        let mut calls = 0;
        let out: Result<()> = dispatch(Site::RunInto, None, || {
            calls += 1;
            Err(Error::Fault { transient: false, msg: "dead".into() })
        });
        assert!(out.is_err());
        assert_eq!(calls, 1);
    }

    #[test]
    fn dispatch_exhausts_budget() {
        let mut calls = 0;
        let out: Result<()> = dispatch(Site::RunLanes, None, || {
            calls += 1;
            Err(Error::Fault { transient: true, msg: "flaky".into() })
        });
        assert!(out.is_err());
        assert_eq!(calls, RETRY_ATTEMPTS);
    }

    #[test]
    fn breaker_full_cycle() {
        let b = Breaker::new("draft", 0, 2, Duration::from_millis(5));
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.opens(), 1);
        assert!(!b.allow(), "cooldown not elapsed");
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.allow(), "first caller after cooldown gets the probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(), "probe already in flight");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.cycles(), 1);
    }

    #[test]
    fn breaker_probe_failure_reopens() {
        let b = Breaker::new("draft", 0, 1, Duration::from_millis(2));
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(4));
        assert!(b.allow());
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.opens(), 2);
        assert_eq!(b.cycles(), 0);
    }

    #[test]
    fn breaker_ungated_success_closes_open_circuit() {
        let b = Breaker::new("target", 1, 1, Duration::from_millis(50));
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        // The target path never consults allow(); a dispatch that
        // completed while the circuit was open proves the backend is
        // healthy. The close is not a probe-driven recovery cycle.
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.cycles(), 0);
    }

    #[test]
    fn breaker_success_resets_streak() {
        let b = Breaker::new("target", 1, 2, Duration::from_millis(50));
        b.record_failure();
        b.record_success();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed, "streak reset by success");
    }

    #[test]
    fn dispatch_records_on_breaker() {
        let b = Breaker::new("draft", 0, 1, Duration::from_millis(50));
        let _: Result<()> = dispatch(Site::RunLanes, Some(&b), || {
            Err(Error::Fault { transient: false, msg: "dead".into() })
        });
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn resilience_renders_all_families() {
        let r = Resilience::new(3, Duration::from_millis(100));
        let text = r.prometheus_text();
        for fam in [
            "specd_faults_injected_total",
            "specd_dispatch_retries_total",
            "specd_lanes_salvaged_total",
            "specd_breaker_state",
            "specd_degraded_mode",
        ] {
            assert!(text.contains(&format!("# TYPE {fam}")), "missing {fam}");
        }
        assert!(text.contains("specd_breaker_state{model=\"draft\"} 0"));
        assert!(text.contains("specd_breaker_state{model=\"target\"} 0"));
        assert!(text.contains("specd_degraded_mode 0"));
        r.draft.record_failure();
        r.draft.record_failure();
        r.draft.record_failure();
        assert!(r.degraded());
        assert!(r.prometheus_text().contains("specd_degraded_mode 1"));
    }

    #[test]
    fn site_roundtrip() {
        for s in [
            Site::RunLanes,
            Site::RunInto,
            Site::PackLane,
            Site::ExecSend,
            Site::IoRead,
            Site::IoWrite,
            Site::SwapStage,
            Site::SwapReadmit,
        ] {
            assert_eq!(Site::from_index(s as u64), Some(s));
        }
        assert_eq!(Site::from_index(99), None);
    }
}

//! Sharded on-disk distillation dataset: framed records + JSON manifest.
//!
//! `specd distill` writes target-generated training data as a directory of
//! shard files plus a manifest:
//!
//! ```text
//! out/
//!   manifest.json      dataset metadata + per-shard checksums
//!   shard-00000.spds   complete shards only (atomic tmp+rename)
//!   shard-00001.spds
//! ```
//!
//! ## Shard layout (little-endian, `SPCD1`-style framing)
//!
//! ```text
//! magic     6 bytes   "SPDS1\0"
//! topk      u16       captured (id, logit) pairs per response position
//! reserved  u16       0
//! then framed records until EOF:
//!   seq_index    u64    global sequence index (contiguous from 0)
//!   task_id      u8     index into the manifest's "mix" list
//!   temperature  f32    target sampling temperature for this record
//!   prompt_len   u32
//!   resp_len     u32
//!   prompt       u32 × prompt_len
//!   response     u32 × resp_len
//!   capture      resp_len × [ids u32 × topk, logits f32 × topk]
//!                (absent when topk = 0; logits are RAW pre-temperature
//!                 rows, descending, so the finetuning step applies its
//!                 own softmax)
//! ```
//!
//! `python/compile/data.py::load_distill_shards` reads the same layout so
//! `train.py` consumes the shards directly.
//!
//! ## Durability / resume
//!
//! Shards are buffered in memory and written in one atomic tmp+rename once
//! complete; the manifest (also tmp+rename) lists complete shards only.
//! Records are committed strictly in `seq_index` order (a small reorder
//! buffer absorbs out-of-order lane completions), so the manifest's
//! `records_total` is exactly the length of the durably-committed prefix
//! `[0, records_total)`. Resume = re-open the directory, discard any stray
//! shard file the manifest doesn't list (a write aborted mid-flight), and
//! regenerate from `records_total` — the seed stream is deterministic
//! ([`crate::workload::SeedStream`]), so the regenerated records are
//! identical and nothing is duplicated.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::Value;
use crate::runtime::TopkRow;

/// Shard file magic.
pub const SHARD_MAGIC: &[u8; 6] = b"SPDS1\x00";
/// Manifest `format` tag.
pub const FORMAT_TAG: &str = "SPDD1";
/// Manifest filename inside a dataset directory.
pub const MANIFEST_NAME: &str = "manifest.json";

/// FNV-1a 64 — the per-shard checksum (no external crates; bit-rot
/// detection is the goal, not collision resistance).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One generated sequence: seed prompt, target response, and (optionally)
/// the target's top-k raw logits per response position.
#[derive(Debug, Clone, PartialEq)]
pub struct DistillRecord {
    pub seq_index: u64,
    pub task: String,
    pub temperature: f32,
    pub prompt: Vec<u32>,
    pub response: Vec<u32>,
    /// One row per response position when capture is on (`meta.topk > 0`),
    /// empty otherwise.
    pub topk: Vec<TopkRow>,
}

/// Dataset-level metadata, persisted in the manifest. On resume it must
/// match the run's configuration exactly: a different mix / seed /
/// temperature grid would produce a different seed stream and break the
/// duplicate-free resume contract.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetMeta {
    /// Captured (id, logit) pairs per response position; 0 disables capture.
    pub topk: usize,
    pub seed: u64,
    /// (task, weight) mixture; record `task_id` indexes into this list.
    pub mix: Vec<(String, f64)>,
    pub temperatures: Vec<f32>,
    pub top_p: f32,
    pub max_new: usize,
    pub records_per_shard: usize,
    /// Provenance (informational, still resume-checked: a different
    /// draft/target/gamma generates different data).
    pub gamma: usize,
    pub draft_model: String,
    pub target_model: String,
}

impl DatasetMeta {
    fn validate(&self) -> Result<()> {
        if self.topk > u16::MAX as usize {
            return Err(Error::msg(format!("topk {} exceeds the u16 shard header", self.topk)));
        }
        if self.mix.is_empty() {
            return Err(Error::msg("dataset meta: empty task mix"));
        }
        if self.mix.len() > u8::MAX as usize {
            return Err(Error::msg("dataset meta: more than 255 tasks"));
        }
        if self.records_per_shard == 0 {
            return Err(Error::msg("records_per_shard must be >= 1"));
        }
        Ok(())
    }

    fn task_id(&self, task: &str) -> Result<u8> {
        self.mix
            .iter()
            .position(|(t, _)| t == task)
            .map(|i| i as u8)
            .ok_or_else(|| Error::msg(format!("record task '{task}' not in the dataset mix")))
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("format", Value::Str(FORMAT_TAG.to_string())),
            ("topk", Value::Num(self.topk as f64)),
            // String, not Num: JSON numbers are f64 and a u64 seed above
            // 2^53 would round, making an identical rerun fail the resume
            // meta check.
            ("seed", Value::Str(self.seed.to_string())),
            (
                "mix",
                Value::Arr(
                    self.mix
                        .iter()
                        .map(|(t, w)| {
                            Value::obj(vec![
                                ("task", Value::Str(t.clone())),
                                ("weight", Value::Num(*w)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "temperatures",
                Value::Arr(self.temperatures.iter().map(|&t| Value::Num(t as f64)).collect()),
            ),
            ("top_p", Value::Num(self.top_p as f64)),
            ("max_new", Value::Num(self.max_new as f64)),
            ("records_per_shard", Value::Num(self.records_per_shard as f64)),
            ("gamma", Value::Num(self.gamma as f64)),
            ("draft_model", Value::Str(self.draft_model.clone())),
            ("target_model", Value::Str(self.target_model.clone())),
        ])
    }

    fn from_json(v: &Value) -> Result<DatasetMeta> {
        if v.req_str("format")? != FORMAT_TAG {
            return Err(Error::Manifest(format!(
                "dataset manifest: format '{}' is not {FORMAT_TAG}",
                v.req_str("format")?
            )));
        }
        let mix = v
            .get("mix")
            .as_arr()
            .ok_or_else(|| Error::Manifest("dataset manifest: missing mix".into()))?
            .iter()
            .map(|e| Ok((e.req_str("task")?.to_string(), e.req_f64("weight")?)))
            .collect::<Result<Vec<_>>>()?;
        let temperatures = v
            .get("temperatures")
            .as_arr()
            .ok_or_else(|| Error::Manifest("dataset manifest: missing temperatures".into()))?
            .iter()
            .map(|e| {
                e.as_f64()
                    .map(|t| t as f32)
                    .ok_or_else(|| Error::Manifest("dataset manifest: bad temperature".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let seed_str = v.req_str("seed")?;
        let seed = seed_str
            .parse::<u64>()
            .map_err(|_| Error::Manifest(format!("dataset manifest: bad seed '{seed_str}'")))?;
        Ok(DatasetMeta {
            topk: v.req_usize("topk")?,
            seed,
            mix,
            temperatures,
            top_p: v.req_f64("top_p")? as f32,
            max_new: v.req_usize("max_new")?,
            records_per_shard: v.req_usize("records_per_shard")?,
            gamma: v.req_usize("gamma")?,
            draft_model: v.req_str("draft_model")?.to_string(),
            target_model: v.req_str("target_model")?.to_string(),
        })
    }
}

/// Manifest entry for one complete shard.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardInfo {
    pub file: String,
    pub records: usize,
    pub response_tokens: usize,
    pub bytes: u64,
    pub fnv64: u64,
}

/// This-run totals returned by [`DatasetWriter::finish`].
#[derive(Debug, Clone, Copy, Default)]
pub struct DatasetSummary {
    /// Records durably committed across the dataset's lifetime.
    pub records_total: u64,
    pub response_tokens_total: u64,
    /// Shards / bytes written by THIS run (excludes resumed shards).
    pub shards_written: usize,
    pub bytes_written: u64,
}

/// Checkpointing shard writer. See the module docs for the durability and
/// resume contract.
pub struct DatasetWriter {
    dir: PathBuf,
    meta: DatasetMeta,
    shards: Vec<ShardInfo>,
    /// Records committed at open time (the resume point).
    resumed_records: u64,
    resumed_response_tokens: u64,
    /// Next expected seq_index == contiguously drained record count.
    next_seq_index: u64,
    /// Out-of-order completions waiting for the contiguous prefix.
    pending: BTreeMap<u64, DistillRecord>,
    /// Encoded records of the in-progress shard (header prepended at flush).
    cur: Vec<u8>,
    cur_records: usize,
    cur_response_tokens: usize,
    shards_written: usize,
    bytes_written: u64,
}

impl DatasetWriter {
    /// Open `dir` for appending: fresh directory ⇒ new dataset; existing
    /// manifest ⇒ resume (meta must match exactly; stray shard files not in
    /// the manifest — aborted mid-flight writes — are deleted).
    pub fn open_or_create(dir: &Path, meta: DatasetMeta) -> Result<DatasetWriter> {
        meta.validate()?;
        std::fs::create_dir_all(dir)?;
        let manifest_path = dir.join(MANIFEST_NAME);
        let (shards, resumed_records, resumed_tokens) = if manifest_path.exists() {
            let existing = DatasetReader::open(dir)?;
            // Bit-rot in the committed prefix must surface NOW, not after
            // this run spends its whole budget extending a broken dataset.
            existing.verify()?;
            if existing.meta != meta {
                return Err(Error::Manifest(format!(
                    "dataset at {} was generated with a different configuration; \
                     resume would duplicate or skip records (delete the directory \
                     or rerun with the original flags)",
                    dir.display()
                )));
            }
            let known: Vec<&str> = existing.shards.iter().map(|s| s.file.as_str()).collect();
            for entry in std::fs::read_dir(dir)? {
                let entry = entry?;
                let name = entry.file_name().to_string_lossy().to_string();
                let is_shard = name.starts_with("shard-")
                    && (name.ends_with(".spds") || name.ends_with(".tmp"));
                if is_shard && !known.contains(&name.as_str()) {
                    std::fs::remove_file(entry.path())?;
                }
            }
            let records: u64 = existing.shards.iter().map(|s| s.records as u64).sum();
            let tokens: u64 = existing.shards.iter().map(|s| s.response_tokens as u64).sum();
            (existing.shards, records, tokens)
        } else {
            (Vec::new(), 0, 0)
        };
        let mut w = DatasetWriter {
            dir: dir.to_path_buf(),
            meta,
            shards,
            resumed_records,
            resumed_response_tokens: resumed_tokens,
            next_seq_index: resumed_records,
            pending: BTreeMap::new(),
            cur: Vec::new(),
            cur_records: 0,
            cur_response_tokens: 0,
            shards_written: 0,
            bytes_written: 0,
        };
        // A valid (possibly empty) manifest exists from the first moment, so
        // an interrupted run before the first shard still resumes cleanly.
        w.write_manifest()?;
        Ok(w)
    }

    /// Records durably committed before this run (the seed-stream
    /// fast-forward distance).
    pub fn resume_records(&self) -> u64 {
        self.resumed_records
    }

    /// Response tokens durably committed before this run.
    pub fn resume_response_tokens(&self) -> u64 {
        self.resumed_response_tokens
    }

    /// Append one record. Records may arrive out of `seq_index` order
    /// (lanes finish when they finish); they are committed in order, and a
    /// duplicate or already-committed index is an error.
    pub fn append(&mut self, rec: DistillRecord) -> Result<()> {
        if rec.seq_index < self.next_seq_index || self.pending.contains_key(&rec.seq_index) {
            return Err(Error::msg(format!(
                "duplicate record seq_index {} (next expected {})",
                rec.seq_index, self.next_seq_index
            )));
        }
        self.pending.insert(rec.seq_index, rec);
        while let Some(rec) = self.pending.remove(&self.next_seq_index) {
            let task_id = self.meta.task_id(&rec.task)?;
            // Encode to a scratch buffer first so a malformed record cannot
            // leave half a frame in the shard.
            let mut frame = Vec::new();
            encode_record(&mut frame, &rec, task_id, self.meta.topk)?;
            self.cur.extend_from_slice(&frame);
            self.next_seq_index += 1;
            self.cur_records += 1;
            self.cur_response_tokens += rec.response.len();
            if self.cur_records == self.meta.records_per_shard {
                self.flush_shard()?;
            }
        }
        Ok(())
    }

    /// Flush the in-progress shard (short final shards are fine) and write
    /// the final manifest. Errors if out-of-order records never filled in —
    /// a hole would silently corrupt the resume contract.
    pub fn finish(mut self) -> Result<DatasetSummary> {
        if let Some((&idx, _)) = self.pending.iter().next() {
            return Err(Error::msg(format!(
                "record stream has a hole: seq_index {} missing, {} held back",
                self.next_seq_index, idx
            )));
        }
        if self.cur_records > 0 {
            self.flush_shard()?;
        } else {
            self.write_manifest()?;
        }
        Ok(DatasetSummary {
            records_total: self.next_seq_index,
            response_tokens_total: self
                .shards
                .iter()
                .map(|s| s.response_tokens as u64)
                .sum::<u64>(),
            shards_written: self.shards_written,
            bytes_written: self.bytes_written,
        })
    }

    fn flush_shard(&mut self) -> Result<()> {
        let mut bytes = Vec::with_capacity(10 + self.cur.len());
        bytes.extend_from_slice(SHARD_MAGIC);
        bytes.extend_from_slice(&(self.meta.topk as u16).to_le_bytes());
        bytes.extend_from_slice(&0u16.to_le_bytes());
        bytes.extend_from_slice(&self.cur);
        let info = ShardInfo {
            file: format!("shard-{:05}.spds", self.shards.len()),
            records: self.cur_records,
            response_tokens: self.cur_response_tokens,
            bytes: bytes.len() as u64,
            fnv64: fnv1a64(&bytes),
        };
        write_atomic(&self.dir.join(&info.file), &bytes)?;
        self.bytes_written += info.bytes;
        self.shards_written += 1;
        self.shards.push(info);
        self.cur.clear();
        self.cur_records = 0;
        self.cur_response_tokens = 0;
        self.write_manifest()
    }

    fn write_manifest(&self) -> Result<()> {
        let records_total: u64 = self.shards.iter().map(|s| s.records as u64).sum();
        let tokens_total: u64 = self.shards.iter().map(|s| s.response_tokens as u64).sum();
        let mut obj = match self.meta.to_json() {
            Value::Obj(o) => o,
            _ => unreachable!("meta serializes to an object"),
        };
        obj.insert("records_total".into(), Value::Num(records_total as f64));
        obj.insert("response_tokens_total".into(), Value::Num(tokens_total as f64));
        obj.insert(
            "shards".into(),
            Value::Arr(
                self.shards
                    .iter()
                    .map(|s| {
                        Value::obj(vec![
                            ("file", Value::Str(s.file.clone())),
                            ("records", Value::Num(s.records as f64)),
                            ("response_tokens", Value::Num(s.response_tokens as f64)),
                            ("bytes", Value::Num(s.bytes as f64)),
                            ("fnv64", Value::Str(format!("{:016x}", s.fnv64))),
                        ])
                    })
                    .collect(),
            ),
        );
        write_atomic(
            &self.dir.join(MANIFEST_NAME),
            Value::Obj(obj).to_string_pretty().as_bytes(),
        )
    }
}

/// tmp + fsync + rename + fsync(dir): the rename must not reach disk
/// before the data blocks do, or a power loss leaves a manifest-listed
/// shard full of garbage — which `open_or_create`'s verify pass would
/// reject, bricking resume for the whole dataset.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    // Retry wrapper: the tmp + rename protocol is idempotent, so a
    // transient failure (injected or real) can simply run again.
    crate::faults::dispatch(crate::faults::Site::IoWrite, None, || {
        // lint: fault-site(io-write)
        crate::faults::inject(crate::faults::Site::IoWrite)?;
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        if let Some(dir) = path.parent() {
            // Persist the rename itself (directory entry). Directories can't
            // be fsynced on some platforms (e.g. Windows); best effort there.
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    })
}

/// Reader for a dataset directory: manifest + checksum-verified shards.
pub struct DatasetReader {
    dir: PathBuf,
    pub meta: DatasetMeta,
    pub shards: Vec<ShardInfo>,
    pub records_total: u64,
    pub response_tokens_total: u64,
}

impl DatasetReader {
    pub fn open(dir: &Path) -> Result<DatasetReader> {
        // lint: fault-site(io-read-manifest)
        crate::faults::inject(crate::faults::Site::IoRead)?;
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Manifest(format!("cannot read {}: {e}", path.display())))?;
        let v = Value::parse(&text)?;
        let meta = DatasetMeta::from_json(&v)?;
        let shards = v
            .get("shards")
            .as_arr()
            .ok_or_else(|| Error::Manifest("dataset manifest: missing shards".into()))?
            .iter()
            .map(|s| {
                let hex = s.req_str("fnv64")?;
                let fnv64 = u64::from_str_radix(hex, 16)
                    .map_err(|_| Error::Manifest(format!("bad shard checksum '{hex}'")))?;
                Ok(ShardInfo {
                    file: s.req_str("file")?.to_string(),
                    records: s.req_usize("records")?,
                    response_tokens: s.req_usize("response_tokens")?,
                    bytes: s.req_usize("bytes")? as u64,
                    fnv64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let records_total = v.req_usize("records_total")? as u64;
        if shards.iter().map(|s| s.records as u64).sum::<u64>() != records_total {
            return Err(Error::Manifest("dataset manifest: records_total mismatch".into()));
        }
        Ok(DatasetReader {
            dir: dir.to_path_buf(),
            response_tokens_total: v.req_usize("response_tokens_total")? as u64,
            meta,
            shards,
            records_total,
        })
    }

    /// Read and fully validate shard `i`: byte count + FNV checksum against
    /// the manifest, record framing, and `seq_index` contiguity.
    pub fn read_shard(&self, i: usize) -> Result<Vec<DistillRecord>> {
        let info = self
            .shards
            .get(i)
            .ok_or_else(|| Error::Manifest(format!("no shard index {i}")))?;
        let path = self.dir.join(&info.file);
        // lint: fault-site(io-read-shard)
        crate::faults::inject(crate::faults::Site::IoRead)?;
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Manifest(format!("cannot read {}: {e}", path.display())))?;
        if bytes.len() as u64 != info.bytes {
            return Err(Error::Manifest(format!(
                "{}: {} bytes on disk, manifest says {}",
                info.file,
                bytes.len(),
                info.bytes
            )));
        }
        let sum = fnv1a64(&bytes);
        if sum != info.fnv64 {
            return Err(Error::Manifest(format!(
                "{}: checksum mismatch ({sum:016x} != {:016x})",
                info.file, info.fnv64
            )));
        }
        let mut cur = Cursor { bytes: &bytes[..], pos: 0 };
        if cur.take(6)? != SHARD_MAGIC {
            return Err(Error::Manifest(format!("{}: bad shard magic", info.file)));
        }
        let topk = cur.u16()? as usize;
        if topk != self.meta.topk {
            return Err(Error::Manifest(format!(
                "{}: shard topk {topk} != manifest topk {}",
                info.file, self.meta.topk
            )));
        }
        let _reserved = cur.u16()?;
        let mut expected: u64 = self.shards[..i].iter().map(|s| s.records as u64).sum();
        let mut out = Vec::with_capacity(info.records);
        while cur.pos < bytes.len() {
            let rec = decode_record(&mut cur, &self.meta, topk)?;
            if rec.seq_index != expected {
                return Err(Error::Manifest(format!(
                    "{}: seq_index {} where {expected} expected",
                    info.file, rec.seq_index
                )));
            }
            expected += 1;
            out.push(rec);
        }
        if out.len() != info.records {
            return Err(Error::Manifest(format!(
                "{}: {} records on disk, manifest says {}",
                info.file,
                out.len(),
                info.records
            )));
        }
        Ok(out)
    }

    /// All records across all shards, fully validated.
    pub fn read_all(&self) -> Result<Vec<DistillRecord>> {
        let mut out = Vec::new();
        for i in 0..self.shards.len() {
            out.extend(self.read_shard(i)?);
        }
        Ok(out)
    }

    /// Validate every shard without keeping records in memory.
    pub fn verify(&self) -> Result<()> {
        for i in 0..self.shards.len() {
            self.read_shard(i)?;
        }
        Ok(())
    }
}

fn encode_record(out: &mut Vec<u8>, rec: &DistillRecord, task_id: u8, topk: usize) -> Result<()> {
    if topk > 0 && rec.topk.len() != rec.response.len() {
        return Err(Error::msg(format!(
            "record {}: {} capture rows for {} response tokens",
            rec.seq_index,
            rec.topk.len(),
            rec.response.len()
        )));
    }
    out.extend_from_slice(&rec.seq_index.to_le_bytes());
    out.push(task_id);
    out.extend_from_slice(&rec.temperature.to_le_bytes());
    out.extend_from_slice(&(rec.prompt.len() as u32).to_le_bytes());
    out.extend_from_slice(&(rec.response.len() as u32).to_le_bytes());
    for &t in &rec.prompt {
        out.extend_from_slice(&t.to_le_bytes());
    }
    for &t in &rec.response {
        out.extend_from_slice(&t.to_le_bytes());
    }
    if topk > 0 {
        for row in &rec.topk {
            if row.ids.len() != topk || row.logits.len() != topk {
                return Err(Error::msg(format!(
                    "record {}: capture row has {} entries, dataset topk is {topk}",
                    rec.seq_index,
                    row.ids.len()
                )));
            }
            for &id in &row.ids {
                out.extend_from_slice(&id.to_le_bytes());
            }
            for &l in &row.logits {
                out.extend_from_slice(&l.to_le_bytes());
            }
        }
    }
    Ok(())
}

fn decode_record(cur: &mut Cursor<'_>, meta: &DatasetMeta, topk: usize) -> Result<DistillRecord> {
    let seq_index = cur.u64()?;
    let task_id = cur.u8()? as usize;
    let task = meta
        .mix
        .get(task_id)
        .map(|(t, _)| t.clone())
        .ok_or_else(|| Error::Manifest(format!("record {seq_index}: task_id {task_id} out of range")))?;
    let temperature = cur.f32()?;
    let prompt_len = cur.u32()? as usize;
    let resp_len = cur.u32()? as usize;
    let mut prompt = Vec::with_capacity(prompt_len);
    for _ in 0..prompt_len {
        prompt.push(cur.u32()?);
    }
    let mut response = Vec::with_capacity(resp_len);
    for _ in 0..resp_len {
        response.push(cur.u32()?);
    }
    let mut rows = Vec::new();
    if topk > 0 {
        rows.reserve(resp_len);
        for _ in 0..resp_len {
            let mut ids = Vec::with_capacity(topk);
            for _ in 0..topk {
                ids.push(cur.u32()?);
            }
            let mut logits = Vec::with_capacity(topk);
            for _ in 0..topk {
                logits.push(cur.f32()?);
            }
            rows.push(TopkRow { ids, logits });
        }
    }
    Ok(DistillRecord { seq_index, task, temperature, prompt, response, topk: rows })
}

/// Bounds-checked little-endian reader. Deliberately a twin of the
/// private cursor in [`crate::weights`] rather than a shared type: the
/// weights parser's errors must stay `Error::Weights` (its loader matches
/// on that variant to prepend the file path), while shard truncation is a
/// manifest-level error here.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            return Err(Error::Manifest("shard truncated mid-record".into()));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("specd-dataset-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn meta(topk: usize, rps: usize) -> DatasetMeta {
        DatasetMeta {
            topk,
            seed: 7,
            mix: vec![("dolly".into(), 0.5), ("cnndm".into(), 0.3), ("xsum".into(), 0.2)],
            temperatures: vec![0.0, 0.7],
            top_p: 0.95,
            max_new: 16,
            records_per_shard: rps,
            gamma: 3,
            draft_model: "draft_tvdpp_ckpt4".into(),
            target_model: "target".into(),
        }
    }

    fn rec(i: u64, topk: usize) -> DistillRecord {
        let response: Vec<u32> = (0..(3 + i as u32 % 4)).map(|j| 10 + j).collect();
        let rows = (0..response.len())
            .map(|p| TopkRow {
                ids: (0..topk as u32).map(|k| k + p as u32).collect(),
                logits: (0..topk).map(|k| (topk - k) as f32 + i as f32).collect(),
            })
            .collect();
        DistillRecord {
            seq_index: i,
            task: ["dolly", "cnndm", "xsum"][i as usize % 3].to_string(),
            temperature: if i % 2 == 0 { 0.0 } else { 0.7 },
            prompt: vec![1, 3, 5 + i as u32, 4],
            response,
            topk: if topk > 0 { rows } else { Vec::new() },
        }
    }

    #[test]
    fn fnv_known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn roundtrip_multi_shard_with_capture() {
        let dir = tmpdir("roundtrip");
        let mut w = DatasetWriter::open_or_create(&dir, meta(4, 2)).unwrap();
        let recs: Vec<DistillRecord> = (0..5).map(|i| rec(i, 4)).collect();
        for r in &recs {
            w.append(r.clone()).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.records_total, 5);
        assert_eq!(summary.shards_written, 3, "2 + 2 + 1 records");
        assert!(summary.bytes_written > 0);

        let r = DatasetReader::open(&dir).unwrap();
        assert_eq!(r.meta, meta(4, 2));
        assert_eq!(r.shards.len(), 3);
        r.verify().unwrap();
        let back = r.read_all().unwrap();
        assert_eq!(back, recs);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_without_capture() {
        let dir = tmpdir("nocapture");
        let mut w = DatasetWriter::open_or_create(&dir, meta(0, 8)).unwrap();
        for i in 0..3 {
            w.append(rec(i, 0)).unwrap();
        }
        w.finish().unwrap();
        let back = DatasetReader::open(&dir).unwrap().read_all().unwrap();
        assert_eq!(back.len(), 3);
        assert!(back.iter().all(|r| r.topk.is_empty()));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn out_of_order_appends_commit_in_order() {
        let dir = tmpdir("ooo");
        let mut w = DatasetWriter::open_or_create(&dir, meta(0, 4)).unwrap();
        // Lanes finish out of order; commit order must still be 0,1,2,3.
        for i in [2u64, 0, 3, 1] {
            w.append(rec(i, 0)).unwrap();
        }
        assert!(w.append(rec(1, 0)).is_err(), "duplicate rejected");
        w.finish().unwrap();
        let back = DatasetReader::open(&dir).unwrap().read_all().unwrap();
        let idx: Vec<u64> = back.iter().map(|r| r.seq_index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn finish_rejects_holes() {
        let dir = tmpdir("hole");
        let mut w = DatasetWriter::open_or_create(&dir, meta(0, 4)).unwrap();
        w.append(rec(0, 0)).unwrap();
        w.append(rec(2, 0)).unwrap(); // 1 never arrives
        assert!(w.finish().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_detected_by_checksum() {
        let dir = tmpdir("corrupt");
        let mut w = DatasetWriter::open_or_create(&dir, meta(2, 8)).unwrap();
        for i in 0..2 {
            w.append(rec(i, 2)).unwrap();
        }
        w.finish().unwrap();
        let shard = dir.join("shard-00000.spds");
        let mut bytes = std::fs::read(&shard).unwrap();
        let n = bytes.len();
        bytes[n / 2] ^= 0xff;
        std::fs::write(&shard, bytes).unwrap();
        let r = DatasetReader::open(&dir).unwrap();
        assert!(r.read_shard(0).is_err(), "flipped byte must fail the checksum");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_discards_partial_and_continues_without_duplicates() {
        let dir = tmpdir("resume");
        // First run: 3 records at 2/shard. Shard 0 (records 0-1) commits;
        // record 2 is buffered and lost when the writer is dropped
        // (simulated crash: no finish()).
        let mut w = DatasetWriter::open_or_create(&dir, meta(2, 2)).unwrap();
        for i in 0..3 {
            w.append(rec(i, 2)).unwrap();
        }
        drop(w);
        // A stray partial shard from the aborted run.
        std::fs::write(dir.join("shard-00001.spds"), b"partial garbage").unwrap();

        let mut w = DatasetWriter::open_or_create(&dir, meta(2, 2)).unwrap();
        assert_eq!(w.resume_records(), 2, "only the committed shard counts");
        assert!(!dir.join("shard-00001.spds").exists(), "stray shard removed");
        // The deterministic stream regenerates records 2..5 identically.
        for i in 2..5 {
            w.append(rec(i, 2)).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.records_total, 5);

        let back = DatasetReader::open(&dir).unwrap().read_all().unwrap();
        let idx: Vec<u64> = back.iter().map(|r| r.seq_index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3, 4], "contiguous, no duplicates");
        assert_eq!(back, (0..5).map(|i| rec(i, 2)).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_rejects_corrupted_committed_prefix() {
        let dir = tmpdir("resume-corrupt");
        let mut w = DatasetWriter::open_or_create(&dir, meta(2, 2)).unwrap();
        for i in 0..2 {
            w.append(rec(i, 2)).unwrap();
        }
        w.finish().unwrap();
        // Bit-rot in the committed shard: resume must fail up front, not
        // after spending a generation budget extending a broken dataset.
        let shard = dir.join("shard-00000.spds");
        let mut bytes = std::fs::read(&shard).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01;
        std::fs::write(&shard, bytes).unwrap();
        assert!(DatasetWriter::open_or_create(&dir, meta(2, 2)).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_rejects_meta_mismatch() {
        let dir = tmpdir("meta-mismatch");
        let w = DatasetWriter::open_or_create(&dir, meta(2, 2)).unwrap();
        w.finish().unwrap();
        let mut other = meta(2, 2);
        other.seed = 99;
        assert!(DatasetWriter::open_or_create(&dir, other).is_err());
        let mut other = meta(2, 2);
        other.mix.pop();
        assert!(DatasetWriter::open_or_create(&dir, other).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn capture_row_arity_enforced() {
        let dir = tmpdir("arity");
        let mut w = DatasetWriter::open_or_create(&dir, meta(4, 8)).unwrap();
        let mut bad = rec(0, 4);
        bad.topk.pop(); // one row short of response length
        assert!(w.append(bad).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

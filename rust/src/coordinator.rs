//! The serving coordinator: request queue, slot-pool admission control and
//! the batch-stepped scheduler loop.
//!
//! Architecture (vLLM-router-style, adapted to a single-device CPU PJRT
//! backend; with a batched bundle each lockstep phase below is ONE fused
//! `[B, T]` dispatch over a device-resident state arena, otherwise the
//! executables are dispatched per sequence):
//!
//! ```text
//!   clients ──bounded channel (backpressure)──▶ scheduler thread
//!                                              │ admit while the KV SlotPool
//!                                              │ has free slots (max_slots =
//!                                              │ the memory budget; exhausted
//!                                              │ pool defers, never errors)
//!                                              ▼
//!                        admission WAVE: up to lanes_free queued prompts
//!                        chunk-locksteped through the batched prefill
//!                        entry DIRECTLY into arena lanes, ≤ prefill_budget
//!                        prompt tokens per iteration, then
//!                                              ▼
//!                                   one BatchStep per iteration:
//!                                     draft-sync sweep   (all lanes)
//!                                     proposal round j   (all lanes, j<γ)
//!                                     verify sweep       (all lanes)
//!                                              ▼
//!                                      responses channel ──▶ clients
//!                                      per-request delta channel ──▶ HTTP
//!                                      streaming handlers (optional)
//! ```
//!
//! PJRT handles are not `Send`, so the scheduler owns all model state on
//! one thread; concurrency with clients happens through the channels from
//! [`crate::exec`]. Phase-lockstep batching ([`crate::batch::BatchStep`])
//! bounds head-of-line blocking at one speculation block per sequence per
//! iteration and dispatches each phase's executable in one tight loop.
//!
//! Admission: [`crate::kvcache::SlotPool`] is the sole capacity gate. A
//! request is admitted exactly when a slot can be allocated; each slot
//! mirrors its sequence's length so `/metrics` can report resident KV
//! positions. When the pool is exhausted, queued requests wait (the
//! bounded channel provides backpressure further upstream). With a
//! batched bundle, admission drains up to `lanes_free` queued requests
//! per iteration into a [`crate::spec::PrefillWave`]: one fused prefill
//! dispatch per model per chunk advances every admitted prompt at once
//! (ragged lengths drop out of later chunks), directly over the arena
//! lanes the sequences will decode in — a wave of N prompts costs
//! O(ceil(L_max/block)) dispatches and ZERO pack dispatches, where the
//! per-sequence path cost O(Σ ceil(L_i/block)) + N packs.
//! `prefill_budget` caps the prompt tokens one iteration may prefill, so
//! a long wave is sliced across iterations and resident lanes keep
//! getting speculation blocks in between (chunked-prefill interleaving:
//! the TTFT-vs-ITL trade is an explicit, metered knob). Pool capacity
//! beyond the arena (or a pre-batched bundle) falls back to per-sequence
//! owned-state admission. Pool errors during admission fail only the one
//! request (lanes and slot released, error response emitted) — never the
//! scheduler loop.
//!
//! Streaming: a request may carry an `events` sender; the scheduler pushes
//! [`Delta::Started`] at admission, a [`Delta::Tokens`] after every
//! speculation block and a terminal [`Delta::Done`] mirroring the final
//! [`Response`]. The events channel is probed every iteration — a client
//! that hangs up is cancelled and frees its slot even when no tokens are
//! flowing toward it (exhausted `max_new` budget, capacity-finished
//! sequence), not just when the next delta send fails.
//!
//! Deadlines: a request may carry a wall-clock `deadline` measured from
//! `submitted` (or admission when unset). Expired sequences are evicted
//! with [`ERR_DEADLINE`] in `Response::error`, which the HTTP server maps
//! to `408 Request Timeout`.
//!
//! Lifecycle (PR 10): with a [`crate::lifecycle::Lifecycle`] handle
//! attached, [`Coordinator::serve_supervised`] runs ONE serving *segment*
//! — it can exit early at a block boundary for a validated draft swap or
//! a guarded-adoption rollback, carrying every resident request out as a
//! [`ResumeState`] (sequence, RNG, streaming offset, deadline, stats).
//! The supervisor ([`crate::lifecycle::run_supervised`]) owns the models
//! across segments, re-admits residents into the next one (re-prefill +
//! bookkeeping transplant — the same machinery as lane salvage, so
//! emitted prefixes stay token-identical and `terminal()` still fires
//! exactly once per request), and `catch_unwind`s the whole segment so a
//! scheduler panic becomes a supervised restart instead of a dead
//! process.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::batch::{BatchStep, Lane, LaneOutcome};
use crate::config::{RunConfig, SamplingConfig};
use crate::error::Result;
use crate::exec::{Receiver, Sender};
use crate::kvcache::{SlotId, SlotPool};
use crate::lifecycle::{Lifecycle, ReloadSpec, State as LcState};
use crate::metrics::{SchedulerGauges, ServeMetrics, SpecStats};
use crate::rng::Pcg64;
use crate::spec::{LogitCapture, PrefillWave, SpecDecoder, SpecSession};

/// `Response::error` value for deadline-evicted requests (HTTP 408).
pub const ERR_DEADLINE: &str = "deadline exceeded";
/// `Response::error` value for client-disconnect cancellations.
pub const ERR_DISCONNECT: &str = "client disconnected";
/// Lane-salvage rounds one request may consume before it is evicted —
/// each round re-prefills the suspect sequence into fresh arena lanes,
/// so a lane that keeps getting quarantined has a persistent fault
/// behind it, not bad luck.
pub const SALVAGE_CAP: u32 = 3;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub sampling: SamplingConfig,
    /// Wall-clock budget measured from `submitted`; `None` = no limit.
    pub deadline: Option<Duration>,
    /// When the client enqueued the request (queue wait counts against the
    /// deadline and the reported latency); admission time when `None`.
    pub submitted: Option<Instant>,
    /// Incremental output sink: [`Delta::Started`] at admission, one
    /// [`Delta::Tokens`] per speculation block, then [`Delta::Done`]. The
    /// channel should be sized so the scheduler never blocks
    /// (`max_new + 3` suffices: every block emits at least one token).
    pub events: Option<Sender<Delta>>,
    /// Task-mix tag for telemetry slicing (e.g. the workload task name or
    /// a client-supplied label); interned once at admission.
    pub tag: Option<String>,
}

impl Request {
    /// A plain request with no deadline and no streaming sink.
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize, sampling: SamplingConfig) -> Request {
        Request {
            id,
            prompt,
            max_new,
            sampling,
            deadline: None,
            submitted: None,
            events: None,
            tag: None,
        }
    }
}

/// Incremental output event for one request (streaming mode).
#[derive(Debug, Clone)]
pub enum Delta {
    /// The request left the admission queue and its prefill started
    /// (joined an admission wave, or began per-sequence prefill). Lets
    /// the HTTP layer distinguish a healthy-but-deep queue (no events
    /// yet) from a post-admission scheduler stall.
    Started,
    /// Tokens emitted by one speculation block, already clipped to the
    /// request's `max_new` budget.
    Tokens(Vec<u32>),
    /// Terminal event; mirrors the [`Response`] sent on the shared
    /// response channel (including the error cases).
    Done(Response),
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated tokens (prompt excluded), truncated to max_new.
    pub tokens: Vec<u32>,
    /// Engine counters, clipped to the delivered token count (so block
    /// efficiency describes what the client received).
    pub stats: crate::metrics::SpecStats,
    /// Queue + decode latency, seconds.
    pub latency: f64,
    /// Time to first emitted token, seconds. Equals `latency` when the
    /// request terminated (deadline, error, cancel) before emitting
    /// anything — never 0.0, which would poison windowed percentiles.
    pub ttft: f64,
    /// Error message when generation failed.
    pub error: Option<String>,
    /// Acceptance-depth histogram for this request: `depth_counts[k]` is
    /// the number of speculation blocks that accepted exactly `k` draft
    /// tokens (`k` in `0..=γ`). Empty for requests that never decoded.
    /// Feeds the `specd_accept_depth` Prometheus histogram; its weighted
    /// sum equals `stats.accepted` before `max_new` clipping.
    pub depth_counts: Vec<u32>,
    /// Per-token inter-token gaps, seconds (`tokens.len() - 1` entries at
    /// most; a block's gap is averaged across the tokens it emitted).
    /// Feeds the `specd_itl_seconds` histogram in both aggregates.
    pub itl: Vec<f64>,
}

/// Everything needed to rebuild one resident request in a different
/// serving segment (draft swap, rollback, or supervised restart).
/// Sequence, sampling state, streaming offset and deadline are exact —
/// re-admission re-prefills `seq` (prompt ++ emitted) and decoding
/// resumes mid-stream with no duplicated or lost deltas. Records built
/// by [`Coordinator::serve_supervised`]'s dismantle path carry full
/// latency bookkeeping too; records rebuilt from the panic-survival
/// registry ([`crate::lifecycle::Lifecycle::drain_registry`]) restart
/// the timing fields (documented fidelity loss — tokens never drift).
pub struct ResumeState {
    pub id: u64,
    /// prompt ++ emitted tokens — the exact sequence to re-prefill.
    pub seq: Vec<u32>,
    pub prompt_len: usize,
    pub sampling: SamplingConfig,
    pub max_new: usize,
    /// RNG mid-stream snapshot: sampled continuations stay on the draw
    /// sequence they would have followed without the interruption.
    pub rng: Pcg64,
    pub enqueued: Instant,
    pub first_token: Option<f64>,
    pub deadline_at: Option<Instant>,
    pub events: Option<Sender<Delta>>,
    /// Tokens already streamed (max_new clipping continues from here).
    pub streamed: usize,
    pub depth_counts: Vec<u32>,
    /// Telemetry tag (re-interned in the new segment — slots don't
    /// survive a coordinator).
    pub tag: Option<String>,
    pub last_emit: Option<f64>,
    pub itl: Vec<f64>,
    pub salvages: u32,
    pub clean_blocks: u32,
    pub stats: SpecStats,
    pub capture: Option<LogitCapture>,
    /// Whether admission was ever announced (`Delta::Started`): started
    /// residents re-prefill + transplant, unstarted ones re-queue through
    /// normal admission (which sends `Started` for the first time).
    pub started: bool,
}

/// Why a supervised serving segment returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Exit {
    /// Request channel closed and all work drained (terminal exit).
    Drained,
    /// A staged draft bundle passed validation; the supervisor should
    /// install it and resume the residents.
    Swap,
    /// A guard trigger fired; reason uses the trace encoding
    /// (0 drift, 1 accept floor, 2 breaker open).
    Rollback(u64),
}

/// What a supervised serving segment hands back to the supervisor.
pub struct ServeOutcome {
    pub metrics: ServeMetrics,
    pub exit: Exit,
    /// Residents to re-admit into the next segment (empty on `Drained`).
    pub residents: Vec<ResumeState>,
}

/// Post-swap probation window: baselines captured at adoption so the
/// triggers fire on what the NEW draft does, not inherited conditions.
#[derive(Debug, Clone, Copy)]
pub struct GuardSpec {
    /// Window length in speculation blocks (summed across lanes).
    pub guard_blocks: usize,
    /// Minimum in-guard acceptance rate; `0.0` disables the floor.
    pub accept_floor: f64,
    /// Whether the drift CUSUM was already firing at adoption (rollback
    /// triggers on the rising edge only).
    pub drift_at_entry: bool,
    /// Draft-breaker open count at adoption.
    pub opens_at_entry: u64,
}

/// Minimum in-guard blocks before the acceptance floor is evaluated:
/// an unlucky first block or two must not condemn a healthy draft.
pub const GUARD_FLOOR_MIN_BLOCKS: u64 = 16;

struct Active {
    id: u64,
    session: SpecSession,
    sampling: SamplingConfig,
    max_new: usize,
    rng: Pcg64,
    enqueued: Instant,
    first_token: Option<f64>,
    /// Absolute eviction deadline, when the request carries one.
    deadline_at: Option<Instant>,
    events: Option<Sender<Delta>>,
    /// Tokens already pushed through `events` (max_new clipping).
    streamed: usize,
    /// The KV pool slot this sequence occupies (freed on every exit path).
    slot: SlotId,
    /// Per-request acceptance-depth counts (`len == γ + 1`), indexed by
    /// accepted-token count per block; snapshotted into the [`Response`].
    depth_counts: Vec<u32>,
    /// Interned telemetry tag slot (0 = untagged).
    tag_slot: u16,
    /// Seconds-from-enqueue of the previous emit (ITL measurement).
    last_emit: Option<f64>,
    /// Per-token inter-token gaps accumulated so far.
    itl: Vec<f64>,
    /// Lane-salvage rounds this request has consumed (capped at
    /// [`SALVAGE_CAP`]; a request quarantined beyond that is evicted).
    salvages: u32,
    /// Consecutive clean (non-quarantined) blocks since the last salvage;
    /// at `salvage_reset_blocks` the salvage count resets so transient
    /// faults spread over a long stream cannot accumulate to eviction.
    clean_blocks: u32,
    /// Telemetry tag retained as a string so a resumed request can
    /// re-intern it in a different segment's telemetry.
    tag: Option<String>,
}

impl Active {
    fn expired(&self) -> bool {
        self.deadline_at.is_some_and(|d| Instant::now() >= d)
    }

    /// A streaming client whose receiver hung up. Probed every iteration:
    /// detection must not depend on a token send happening to fail.
    fn disconnected(&self) -> bool {
        self.events.as_ref().is_some_and(|ev| !ev.is_connected())
    }
}

/// A request accepted off the channel, waiting for admission capacity.
/// Its deadline/disconnect state is re-probed every iteration it waits,
/// so queued work that expired or hung up never spends a prefill.
struct Pending {
    req: Request,
    enqueued: Instant,
    deadline_at: Option<Instant>,
}

impl Pending {
    fn disconnected(&self) -> bool {
        self.req.events.as_ref().is_some_and(|ev| !ev.is_connected())
    }
}

/// An admission wave in flight across scheduler iterations: the engine's
/// chunk-lockstep cursor plus the pending requests it will admit (aligned
/// with the wave's lanes, in order).
struct WaveInFlight {
    wave: PrefillWave,
    members: Vec<Pending>,
}

/// The scheduler. Owns the models (via the decoder) for its lifetime.
pub struct Coordinator<'a> {
    decoder: SpecDecoder<'a>,
    cfg: RunConfig,
    gauges: Option<Arc<SchedulerGauges>>,
    telemetry: Option<Arc<crate::telemetry::Telemetry>>,
    log_requests: bool,
    lifecycle: Option<Arc<Lifecycle>>,
}

impl<'a> Coordinator<'a> {
    pub fn new(decoder: SpecDecoder<'a>, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Coordinator {
            decoder,
            cfg,
            gauges: None,
            telemetry: None,
            log_requests: false,
            lifecycle: None,
        })
    }

    /// Attach live gauges (shared with the HTTP `/metrics` handler).
    pub fn with_gauges(mut self, gauges: Arc<SchedulerGauges>) -> Self {
        self.gauges = Some(gauges);
        self
    }

    /// Attach the windowed telemetry ring (shared with `/debug/stats`).
    /// The scheduler feeds it per block and per iteration; a disabled
    /// handle costs one relaxed load per site.
    pub fn with_telemetry(mut self, telemetry: Arc<crate::telemetry::Telemetry>) -> Self {
        self.telemetry = Some(telemetry);
        self
    }

    /// Emit one structured JSON access-log line per request terminal on
    /// stderr (`--log-requests`).
    pub fn with_access_log(mut self, on: bool) -> Self {
        self.log_requests = on;
        self
    }

    /// Attach the shared lifecycle handle: enables the reload mailbox,
    /// the panic-survival registry feed and the chaos panic trip. Without
    /// it the scheduler behaves exactly as before PR 10.
    pub fn with_lifecycle(mut self, lifecycle: Arc<Lifecycle>) -> Self {
        self.lifecycle = Some(lifecycle);
        self
    }

    /// Serve until the request channel closes and all work drains.
    /// Returns aggregate metrics.
    pub fn serve(&self, rx: Receiver<Request>, tx: Sender<Response>) -> Result<ServeMetrics> {
        self.serve_supervised(&rx, &tx, Vec::new(), None, None).map(|o| o.metrics)
    }

    /// Run ONE supervised serving segment: serve until the channel drains
    /// (like [`Self::serve`]), a validated draft swap quiesces the
    /// segment, or a guard trigger demands a rollback — the latter two
    /// exit at a block boundary with every resident request dismantled
    /// into [`ResumeState`]s for the supervisor to re-admit.
    ///
    /// `stager` runs on this (scheduler) thread when a reload is pending:
    /// it stages + validates the candidate bundle and parks the staged
    /// model supervisor-side; a staging error rejects the reload with
    /// zero serving impact. `guard` arms the post-swap probation window.
    pub fn serve_supervised(
        &self,
        rx: &Receiver<Request>,
        tx: &Sender<Response>,
        resume: Vec<ResumeState>,
        mut stager: Option<&mut dyn FnMut(&ReloadSpec) -> Result<()>>,
        guard: Option<GuardSpec>,
    ) -> Result<ServeOutcome> {
        let mut metrics = ServeMetrics::default();
        // Histogram families with fixed bounds, so merged/scraped quantiles
        // survive aggregation (and scrape resets — the micro-fix for the
        // Summary-style queue-wait samples losing history).
        metrics.accept_depth = crate::metrics::Histogram::accept_depth(self.cfg.gamma);
        metrics.block_draft_sync =
            crate::metrics::Histogram::with_bounds(&crate::metrics::BLOCK_SECONDS_BOUNDS);
        metrics.block_propose =
            crate::metrics::Histogram::with_bounds(&crate::metrics::BLOCK_SECONDS_BOUNDS);
        metrics.block_verify =
            crate::metrics::Histogram::with_bounds(&crate::metrics::BLOCK_SECONDS_BOUNDS);
        metrics.queue_wait_hist =
            crate::metrics::Histogram::with_bounds(&crate::metrics::QUEUE_WAIT_BOUNDS);
        metrics.ttft_hist = crate::metrics::Histogram::with_bounds(&crate::metrics::TTFT_BOUNDS);
        metrics.itl_hist = crate::metrics::Histogram::with_bounds(&crate::metrics::ITL_BOUNDS);
        // Fused-dispatch arenas, when the bundle exports batched entry
        // points. Admitted sessions are adopted into them (arena-capacity
        // permitting) so every lockstep phase is one PJRT dispatch;
        // un-adopted sessions run per-lane within the same batch step.
        let mut batched = self.decoder.batched_ctx()?;
        // Slot capacity: the sequence mirror can exceed the processed
        // positions by exactly one — the final bonus token is appended to
        // the sequence but never reprocessed.
        let slot_cap = self.decoder.target.max_seq() + 1;
        let mut pool: SlotPool<u64> = SlotPool::new(self.cfg.max_slots);
        if let Some(g) = &self.gauges {
            g.pool_max.store(pool.max_slots(), Ordering::Relaxed);
        }
        let mut active: Vec<Active> = Vec::new();
        // Requests accepted off the channel, waiting for lane/slot
        // capacity; re-probed for deadline/disconnect while they wait.
        let mut pending: VecDeque<Pending> = VecDeque::new();
        // The admission wave in flight (at most one), sliced across
        // iterations by the prefill budget.
        let mut wave: Option<WaveInFlight> = None;
        let prefill_budget =
            if self.cfg.prefill_budget == 0 { usize::MAX } else { self.cfg.prefill_budget };
        // Checked once: a bundle that can't lockstep waves (mismatched
        // prefill blocks) serves per-sequence instead of failing waves.
        let wave_capable = self.decoder.wave_capable();
        let mut rx_open = true;
        let wall0 = Instant::now();

        // --- resume: re-admit residents carried over from the previous
        // segment (draft swap, rollback, or supervised restart). Started
        // residents re-prefill + transplant mid-stream; unstarted ones
        // re-queue through normal admission (first Delta::Started).
        if !resume.is_empty() {
            let mut started: Vec<ResumeState> = Vec::new();
            for r in resume {
                if r.started {
                    started.push(r);
                } else {
                    let mut prompt = r.seq;
                    prompt.truncate(r.prompt_len);
                    pending.push_back(Pending {
                        enqueued: r.enqueued,
                        deadline_at: r.deadline_at,
                        req: Request {
                            id: r.id,
                            prompt,
                            max_new: r.max_new,
                            sampling: r.sampling,
                            deadline: None,
                            submitted: Some(r.enqueued),
                            events: r.events,
                            tag: r.tag,
                        },
                    });
                }
            }
            self.readmit(&mut batched, &mut pool, tx, started, &mut active, slot_cap);
        }

        // Guard-window accounting (post-swap probation): blocks and
        // accept counts accumulated while the guard is armed.
        let mut guard = guard;
        let (mut guard_blocks, mut guard_accepted, mut guard_drafted) = (0u64, 0u64, 0u64);

        loop {
            // --- lifecycle checks, at a block boundary ---------------
            if let Some(lc) = &self.lifecycle {
                if lc.take_panic_trip() {
                    // lint: allow(no-panic, chaos hook: deliberately exercises the supervised restart path)
                    panic!("scheduler panic tripped via lifecycle chaos hook");
                }
                if let Some(spec) = lc.take_reload() {
                    match stager.as_mut() {
                        Some(st) => match (*st)(&spec) {
                            Ok(()) => {
                                // Candidate staged + validated: quiesce
                                // this segment so the supervisor can
                                // install it. Zero-drop: every resident
                                // leaves as a ResumeState.
                                lc.set_state(LcState::Quiescing);
                                let residents = self.dismantle(
                                    &mut batched,
                                    std::mem::take(&mut active),
                                    wave.take(),
                                    std::mem::take(&mut pending),
                                );
                                metrics.pool_peak_slots = pool.peak_live;
                                metrics.wall_seconds = wall0.elapsed().as_secs_f64();
                                return Ok(ServeOutcome {
                                    metrics,
                                    exit: Exit::Swap,
                                    residents,
                                });
                            }
                            Err(e) => lc.record_rejected(&spec.model, &e.to_string()),
                        },
                        None => lc.record_rejected(
                            &spec.model,
                            "reload requested but this serve call has no stager attached",
                        ),
                    }
                }
            }
            if let Some(g) = &guard {
                let mut trigger: Option<u64> = None;
                if let Some(tl) = &self.telemetry {
                    // Rising edge only: drift already active at adoption
                    // was the OLD draft's problem.
                    if !g.drift_at_entry && tl.drift_active() {
                        trigger = Some(0);
                    }
                }
                if trigger.is_none()
                    && g.accept_floor > 0.0
                    && guard_blocks >= GUARD_FLOOR_MIN_BLOCKS
                    && (guard_accepted as f64) < g.accept_floor * (guard_drafted as f64)
                {
                    trigger = Some(1);
                }
                if trigger.is_none() {
                    if let Some(b) = self.decoder.draft.breaker() {
                        if b.opens() > g.opens_at_entry {
                            trigger = Some(2);
                        }
                    }
                }
                if let Some(reason) = trigger {
                    if let Some(lc) = &self.lifecycle {
                        lc.set_state(LcState::Quiescing);
                    }
                    let residents = self.dismantle(
                        &mut batched,
                        std::mem::take(&mut active),
                        wave.take(),
                        std::mem::take(&mut pending),
                    );
                    metrics.pool_peak_slots = pool.peak_live;
                    metrics.wall_seconds = wall0.elapsed().as_secs_f64();
                    return Ok(ServeOutcome {
                        metrics,
                        exit: Exit::Rollback(reason),
                        residents,
                    });
                }
                if guard_blocks >= g.guard_blocks as u64 {
                    // Probation passed: the adoption sticks.
                    guard = None;
                    if let Some(lc) = &self.lifecycle {
                        lc.set_state(LcState::Serving);
                    }
                }
            }
            // --- intake: accept queued requests into the pending set -----
            // Bounded by max_slots so the channel keeps providing
            // backpressure further upstream.
            while rx_open && pending.len() < self.cfg.max_slots {
                let idle = active.is_empty() && wave.is_none() && pending.is_empty();
                let req = if idle {
                    // Fully idle: block for work (or shutdown).
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            rx_open = false;
                            None
                        }
                    }
                } else {
                    rx.try_recv()
                };
                let Some(req) = req else { break };
                let enqueued = req.submitted.unwrap_or_else(Instant::now);
                let deadline_at = req.deadline.map(|d| enqueued + d);
                crate::trace::req_queued(req.id);
                if let Some(lc) = &self.lifecycle {
                    // Panic-survival ledger: the request is resumable from
                    // here until its terminal fires (unregister).
                    lc.register(&req, enqueued, deadline_at);
                }
                pending.push_back(Pending { req, enqueued, deadline_at });
            }

            // --- pending hygiene: expired or hung-up queued requests are
            // rejected before spending a prefill or a pool slot. In-place
            // retain with one clock read: this runs every hot-loop
            // iteration and must not allocate.
            let now = Instant::now();
            pending.retain_mut(|p| {
                if p.deadline_at.is_some_and(|d| now >= d) {
                    metrics.timeouts += 1;
                    let resp = Self::pending_error(p, ERR_DEADLINE.to_string());
                    self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                    false
                } else if p.disconnected() {
                    metrics.cancelled += 1;
                    let resp = Self::pending_error(p, ERR_DISCONNECT.to_string());
                    self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                    false
                } else {
                    true
                }
            });

            // --- admission: fused wave over the batched prefill entry ----
            let t_admit = Instant::now();
            let disp0 = self.decoder.dispatch_count();
            let (mut waves_opened, mut wave_lanes, mut admit_tokens) = (0u64, 0u64, 0usize);

            if let Some(ctx) = batched.as_mut() {
                // Open a new wave over as many pending requests as there
                // is lane AND slot capacity for.
                if wave_capable && wave.is_none() && !pending.is_empty() {
                    let k = pending.len().min(ctx.available()).min(pool.available());
                    let mut members: Vec<Pending> = Vec::with_capacity(k);
                    let mut prompts: Vec<Vec<u32>> = Vec::with_capacity(k);
                    while members.len() < k {
                        let Some(p) = pending.pop_front() else { break };
                        // Per-request validation up front: a bad prompt is
                        // that request's failure, never the wave's.
                        if let Err(e) = self.decoder.validate_prompt(&p.req.prompt) {
                            let resp = Self::pending_error(&p, e.to_string());
                            self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                            continue;
                        }
                        if let Some(ev) = &p.req.events {
                            let _ = ev.send(Delta::Started);
                        }
                        let wait = p.enqueued.elapsed().as_secs_f64();
                        metrics.queue_wait.push(wait);
                        metrics.queue_wait_hist.observe(wait);
                        crate::trace::req_admitted(p.req.id, (wait * 1e6) as u64);
                        if let Some(lc) = &self.lifecycle {
                            lc.note_started(p.req.id);
                        }
                        prompts.push(p.req.prompt.clone());
                        members.push(p);
                    }
                    if !members.is_empty() {
                        match self.decoder.begin_wave(ctx, prompts) {
                            Ok(w) => {
                                waves_opened += 1;
                                wave_lanes += members.len() as u64;
                                metrics.prefill_waves += 1;
                                metrics.prefill_wave_lanes += members.len();
                                wave = Some(WaveInFlight { wave: w, members });
                            }
                            Err(e) => {
                                // begin_wave allocates nothing on failure.
                                for p in members {
                                    let resp = Self::pending_error(&p, e.to_string());
                                    self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                                }
                            }
                        }
                    }
                }
                // Advance the wave by up to `budget` prompt tokens; admit
                // its sessions once it drains.
                if let Some(mut wf) = wave.take() {
                    let tr_w = crate::trace::begin();
                    let wave_members = wf.members.len() as u64;
                    match self.decoder.wave_step(ctx, &mut wf.wave, prefill_budget) {
                        Ok(spent) => {
                            crate::trace::wave(tr_w, wave_members, spent as u64);
                            admit_tokens += spent;
                            if wf.wave.done() {
                                match self.decoder.finish_wave(ctx, wf.wave) {
                                    Ok(sessions) => {
                                        for (p, mut session) in
                                            wf.members.into_iter().zip(sessions)
                                        {
                                            match Self::claim_slot(
                                                &mut pool,
                                                p.req.id,
                                                slot_cap,
                                                session.prompt_len,
                                            ) {
                                                Ok(slot) => active
                                                    .push(self.make_active(p, session, slot)),
                                                Err(e) => {
                                                    // Per-request failure:
                                                    // free the lanes, keep
                                                    // the scheduler alive.
                                                    self.decoder.release(ctx, &mut session);
                                                    let resp =
                                                        Self::pending_error(&p, e.to_string());
                                                    self.terminal(
                                                        tx,
                                                        &p.req.events,
                                                        p.req.prompt.len(),
                                                        resp,
                                                    );
                                                }
                                            }
                                        }
                                    }
                                    Err(e) => {
                                        // finish_wave released every lane.
                                        for p in wf.members {
                                            let resp = Self::pending_error(&p, e.to_string());
                                            self.terminal(
                                                tx,
                                                &p.req.events,
                                                p.req.prompt.len(),
                                                resp,
                                            );
                                        }
                                    }
                                }
                            } else {
                                wave = Some(wf);
                            }
                        }
                        Err(e) => {
                            // Wave-fatal dispatch failure: release the
                            // lanes, fail every member request.
                            self.decoder.abort_wave(ctx, wf.wave);
                            for p in wf.members {
                                let resp = Self::pending_error(&p, e.to_string());
                                self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                            }
                        }
                    }
                }
            }

            // --- admission fallback: per-sequence owned prefill ----------
            // Pre-batched or wave-incapable bundles, or pool capacity
            // beyond the arena (extra residents run per-lane within the
            // same batch step).
            while !pending.is_empty()
                && pool.available() > 0
                && wave.is_none()
                && (!wave_capable || !batched.as_ref().is_some_and(|c| c.available() > 0))
            {
                let Some(p) = pending.pop_front() else { break };
                if let Some(ev) = &p.req.events {
                    let _ = ev.send(Delta::Started);
                }
                let wait = p.enqueued.elapsed().as_secs_f64();
                metrics.queue_wait.push(wait);
                metrics.queue_wait_hist.observe(wait);
                crate::trace::req_admitted(p.req.id, (wait * 1e6) as u64);
                if let Some(lc) = &self.lifecycle {
                    lc.note_started(p.req.id);
                }
                // Prefill (owned state), then pack into the fused arenas
                // if a lane freed meanwhile. An adopt failure poisons only
                // this session — report it like a start failure.
                let started = self.decoder.start(&p.req.prompt).and_then(|mut session| {
                    if let Some(c) = batched.as_mut() {
                        if let Err(e) = self.decoder.adopt(c, &mut session) {
                            self.decoder.release(c, &mut session);
                            return Err(e);
                        }
                    }
                    Ok(session)
                });
                match started {
                    Ok(mut session) => {
                        admit_tokens += session.prompt_len;
                        match Self::claim_slot(&mut pool, p.req.id, slot_cap, session.prompt_len)
                        {
                            Ok(slot) => active.push(self.make_active(p, session, slot)),
                            Err(e) => {
                                // Per-request pool failure (was scheduler-
                                // fatal `?` before): release and report.
                                self.release_lanes(&mut batched, &mut session);
                                let resp = Self::pending_error(&p, e.to_string());
                                self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                            }
                        }
                    }
                    Err(e) => {
                        let resp = Self::pending_error(&p, e.to_string());
                        self.terminal(tx, &p.req.events, p.req.prompt.len(), resp);
                    }
                }
            }

            metrics.prefill_tokens += admit_tokens;
            let admit_dispatches = self.decoder.dispatch_count() - disp0;
            metrics.prefill_dispatches += admit_dispatches;
            let admit_seconds = t_admit.elapsed().as_secs_f64();
            metrics.phase_prefill_seconds += admit_seconds;
            if let Some(g) = &self.gauges {
                g.record_admission(
                    waves_opened,
                    wave_lanes,
                    admit_dispatches,
                    admit_tokens as u64,
                    admit_seconds,
                );
            }

            // Pool exhausted with work still queued: defer admission until
            // a slot frees (the bounded request channel pushes back
            // further upstream) — never an error.
            if pool.available() == 0 && (!pending.is_empty() || !rx.is_empty()) {
                metrics.admission_deferrals += 1;
                if let Some(g) = &self.gauges {
                    g.record_deferral();
                }
            }

            if active.is_empty() {
                if !rx_open && wave.is_none() && pending.is_empty() {
                    break;
                }
                // Keep the telemetry clock and queue-depth gauge advancing
                // while the scheduler spins on admission (deferral phases
                // would otherwise stall the snapshot cadence).
                if let Some(tl) = &self.telemetry {
                    tl.on_iteration(&crate::telemetry::IterSample {
                        queue_depth: (rx.len() + pending.len()) as u64,
                        pool_live: pool.live() as u64,
                        pool_max: pool.max_slots() as u64,
                        degraded: self.degraded(),
                        ..Default::default()
                    });
                }
                continue;
            }

            // --- eviction sweep: deadlines + disconnected clients --------
            let mut survivors = Vec::with_capacity(active.len());
            for mut a in active.drain(..) {
                if a.expired() {
                    metrics.timeouts += 1;
                    pool.free(a.slot)?;
                    self.release_lanes(&mut batched, &mut a.session);
                    let resp = Self::terminal_response(&a, Some(ERR_DEADLINE.to_string()));
                    self.terminal(tx, &a.events, a.session.prompt_len, resp);
                } else if a.disconnected() {
                    metrics.cancelled += 1;
                    pool.free(a.slot)?;
                    self.release_lanes(&mut batched, &mut a.session);
                    let resp = Self::terminal_response(&a, Some(ERR_DISCONNECT.to_string()));
                    self.terminal(tx, &a.events, a.session.prompt_len, resp);
                } else {
                    survivors.push(a);
                }
            }
            active = survivors;
            if active.is_empty() {
                continue;
            }

            // --- one scheduling iteration: a lockstep batch step ---------
            let tr_it = crate::trace::begin();
            // Per-lane (accepted, drafted) snapshot: the post-step deltas
            // are this block's acceptance depth (0..=γ) and proposal
            // count, feeding the `specd_accept_depth` histogram, the
            // per-request counts and the telemetry per-block stream.
            let pre_counters: Vec<(usize, usize)> = active
                .iter()
                .map(|a| (a.session.stats.accepted, a.session.stats.drafted))
                .collect();
            let (outcomes, timings) = {
                let mut lanes: Vec<Lane<'_>> = active
                    .iter_mut()
                    .map(|a| Lane {
                        session: &mut a.session,
                        sampling: a.sampling,
                        rng: &mut a.rng,
                    })
                    .collect();
                BatchStep::run(&self.decoder, batched.as_mut(), &mut lanes)
            };
            metrics.batch_iterations += 1;
            metrics.phase_draft_sync_seconds += timings.draft_sync;
            metrics.phase_propose_seconds += timings.propose;
            metrics.phase_verify_seconds += timings.verify;
            metrics.dispatches += timings.dispatches;
            metrics.lane_steps += timings.lanes;
            metrics.batched_lane_steps += timings.batched_lanes;
            metrics.block_draft_sync.observe(timings.draft_sync);
            metrics.block_propose.observe(timings.propose);
            metrics.block_verify.observe(timings.verify);
            crate::trace::iteration(tr_it, timings.lanes as u64, timings.dispatches);

            let mut survivors = Vec::with_capacity(active.len());
            let mut suspects: Vec<(Active, crate::error::Error)> = Vec::new();
            let mut iter_tokens = 0u64;
            for (i, (mut a, outcome)) in active.drain(..).zip(outcomes).enumerate() {
                match outcome {
                    LaneOutcome::Emitted(emitted) => {
                        let depth = (a.session.stats.accepted - pre_counters[i].0)
                            .min(a.depth_counts.len() - 1);
                        let drafted = a.session.stats.drafted - pre_counters[i].1;
                        metrics.accept_depth.observe(depth as f64);
                        a.depth_counts[depth] += 1;
                        // A clean block: decay the salvage count once the
                        // configured run completes, so transient faults
                        // spread over a long stream never accumulate to
                        // the eviction cap (PR 10 bugfix).
                        a.clean_blocks = a.clean_blocks.saturating_add(1);
                        a.salvages = Self::salvage_decay(
                            a.salvages,
                            a.clean_blocks,
                            self.cfg.salvage_reset_blocks,
                        );
                        if guard.is_some() {
                            guard_blocks += 1;
                            guard_accepted += depth as u64;
                            guard_drafted += drafted as u64;
                        }
                        pool.get_mut(a.slot)?.advance(emitted.len())?;
                        iter_tokens += emitted.len() as u64;
                        let now_s = a.enqueued.elapsed().as_secs_f64();
                        // ITL: this block's emit gap, averaged across its
                        // tokens. The first emit is TTFT, not a gap.
                        let mut itl_gap = None;
                        if let Some(prev) = a.last_emit {
                            if !emitted.is_empty() {
                                let gap = ((now_s - prev) / emitted.len() as f64).max(0.0);
                                itl_gap = Some((gap, emitted.len() as u32));
                                for _ in 0..emitted.len() {
                                    a.itl.push(gap);
                                }
                            }
                        }
                        a.last_emit = Some(now_s);
                        if a.first_token.is_none() {
                            a.first_token = Some(now_s);
                            if let Some(tl) = &self.telemetry {
                                tl.on_ttft(now_s);
                            }
                        }
                        if let Some(tl) = &self.telemetry {
                            tl.on_block(
                                a.tag_slot,
                                depth as u64,
                                drafted as u64,
                                emitted.len() as u64,
                                itl_gap,
                            );
                        }
                        // Stream the block's tokens, clipped to max_new.
                        let mut hung_up = false;
                        if let Some(ev) = &a.events {
                            let budget = a.max_new.saturating_sub(a.streamed);
                            let clip = emitted.len().min(budget);
                            if clip > 0 {
                                if ev.send(Delta::Tokens(emitted[..clip].to_vec())).is_err() {
                                    hung_up = true;
                                } else {
                                    a.streamed += clip;
                                }
                            }
                        }
                        if let Some(lc) = &self.lifecycle {
                            // Post-block snapshot: emitted tokens, the RNG
                            // as left after this block's draws, and the
                            // streamed offset — the resume point.
                            lc.note_block(a.id, &emitted, &a.rng, a.streamed);
                        }
                        if hung_up {
                            metrics.cancelled += 1;
                            pool.free(a.slot)?;
                            self.release_lanes(&mut batched, &mut a.session);
                            let resp =
                                Self::terminal_response(&a, Some(ERR_DISCONNECT.to_string()));
                            self.terminal(tx, &a.events, a.session.prompt_len, resp);
                        } else if a.session.finished || a.session.generated().len() >= a.max_new {
                            pool.free(a.slot)?;
                            self.release_lanes(&mut batched, &mut a.session);
                            self.finish(&mut metrics, tx, &a);
                        } else {
                            survivors.push(a);
                        }
                    }
                    LaneOutcome::Idle => {
                        // Context capacity reached (the session is now
                        // finished): deliver the partial output as a
                        // successful completion.
                        pool.free(a.slot)?;
                        self.release_lanes(&mut batched, &mut a.session);
                        self.finish(&mut metrics, tx, &a);
                    }
                    LaneOutcome::Failed(e) => {
                        pool.free(a.slot)?;
                        self.release_lanes(&mut batched, &mut a.session);
                        let resp = Self::terminal_response(&a, Some(e.to_string()));
                        self.terminal(tx, &a.events, a.session.prompt_len, resp);
                    }
                    LaneOutcome::Suspect(e) => {
                        // Quarantined by a fused dispatch failure: the
                        // request is salvaged after the outcome sweep
                        // (slot kept, arena lanes released, sequence
                        // re-prefilled) instead of evicted.
                        suspects.push((a, e));
                    }
                }
            }
            active = survivors;
            if !suspects.is_empty() {
                self.salvage(&mut batched, &mut pool, tx, suspects, &mut active)?;
            }

            if let Some(g) = &self.gauges {
                g.pool_live.store(pool.live(), Ordering::Relaxed);
                g.pool_peak.store(pool.peak_live, Ordering::Relaxed);
                g.resident_tokens.store(pool.resident(), Ordering::Relaxed);
                g.queue_depth.store(rx.len() + pending.len(), Ordering::Relaxed);
                g.record_iteration(&timings);
            }
            if let Some(tl) = &self.telemetry {
                tl.on_iteration(&crate::telemetry::IterSample {
                    tokens: iter_tokens,
                    dispatches: timings.dispatches,
                    lanes: timings.lanes as u64,
                    queue_depth: (rx.len() + pending.len()) as u64,
                    pool_live: pool.live() as u64,
                    pool_max: pool.max_slots() as u64,
                    degraded: self.degraded(),
                });
            }
        }
        metrics.pool_peak_slots = pool.peak_live;
        metrics.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(ServeOutcome { metrics, exit: Exit::Drained, residents: Vec::new() })
    }

    /// Whether the stack is serving in target-only degraded mode right
    /// now: a draft circuit breaker is attached and not Closed.
    fn degraded(&self) -> bool {
        self.decoder
            .draft
            .breaker()
            .is_some_and(|b| b.state() != crate::faults::BreakerState::Closed)
    }

    /// Return any fused-arena lanes a departing session holds (next to
    /// every `pool.free` — the slot pool and the arenas recycle together).
    fn release_lanes(
        &self,
        batched: &mut Option<crate::spec::BatchedCtx>,
        session: &mut SpecSession,
    ) {
        if let Some(c) = batched.as_mut() {
            self.decoder.release(c, session);
        }
    }

    /// Dismantle the current segment for a swap or rollback exit: every
    /// resident request (active lanes, the admission wave in flight, the
    /// pending queue) becomes a [`ResumeState`]. Arena lanes are returned
    /// (the arena and slot pool are segment-locals and drop with it); NO
    /// terminals fire — the requests are still live, just migrating to
    /// the next segment.
    fn dismantle(
        &self,
        batched: &mut Option<crate::spec::BatchedCtx>,
        active: Vec<Active>,
        wave: Option<WaveInFlight>,
        pending: VecDeque<Pending>,
    ) -> Vec<ResumeState> {
        let mut out = Vec::with_capacity(active.len() + pending.len() + 4);
        for mut a in active {
            self.release_lanes(batched, &mut a.session);
            out.push(ResumeState {
                id: a.id,
                seq: a.session.seq.clone(),
                prompt_len: a.session.prompt_len,
                sampling: a.sampling,
                max_new: a.max_new,
                rng: a.rng,
                enqueued: a.enqueued,
                first_token: a.first_token,
                deadline_at: a.deadline_at,
                events: a.events,
                streamed: a.streamed,
                depth_counts: a.depth_counts,
                tag: a.tag,
                last_emit: a.last_emit,
                itl: a.itl,
                salvages: a.salvages,
                clean_blocks: a.clean_blocks,
                stats: a.session.stats,
                capture: a.session.capture.take(),
                started: true,
            });
        }
        if let Some(wf) = wave {
            if let Some(ctx) = batched.as_mut() {
                self.decoder.abort_wave(ctx, wf.wave);
            }
            for p in wf.members {
                // Delta::Started already went out for wave members, so
                // they resume as started (re-prefill + transplant) and
                // the stream protocol never repeats Started.
                out.push(Self::requeue_state(p, true, self.cfg.gamma));
            }
        }
        for p in pending {
            out.push(Self::requeue_state(p, false, self.cfg.gamma));
        }
        out
    }

    /// [`ResumeState`] for a resident that owns no session yet (admission
    /// wave member or queued pending request). The RNG is recomputed from
    /// the seed — nothing has drawn from it.
    fn requeue_state(p: Pending, started: bool, gamma: usize) -> ResumeState {
        let prompt_len = p.req.prompt.len();
        ResumeState {
            id: p.req.id,
            seq: p.req.prompt,
            prompt_len,
            sampling: p.req.sampling,
            max_new: p.req.max_new,
            rng: Pcg64::with_stream(p.req.sampling.seed ^ p.req.id, 0x5e0e),
            enqueued: p.enqueued,
            first_token: None,
            deadline_at: p.deadline_at,
            events: p.req.events,
            streamed: 0,
            depth_counts: vec![0; gamma + 1],
            tag: p.req.tag,
            last_emit: None,
            itl: Vec::new(),
            salvages: 0,
            clean_blocks: 0,
            stats: Default::default(),
            capture: None,
            started,
        }
    }

    /// Re-admit started residents into this segment: each chunk is ONE
    /// admission wave over the full sequences (prompt ++ emitted), then
    /// the engine bookkeeping is transplanted exactly like lane salvage —
    /// decoding resumes mid-stream, token-identical for everything
    /// already emitted. Failures are per-request terminals ("resume
    /// re-prefill failed"), never a scheduler error: the fresh segment
    /// must come up even when some residents cannot.
    fn readmit(
        &self,
        batched: &mut Option<crate::spec::BatchedCtx>,
        pool: &mut SlotPool<u64>,
        tx: &Sender<Response>,
        residents: Vec<ResumeState>,
        active: &mut Vec<Active>,
        slot_cap: usize,
    ) {
        let mut queue: VecDeque<ResumeState> = residents.into();
        // Fused path: wave-sized chunks bounded by lane + slot capacity.
        while !queue.is_empty() {
            let cap = match batched.as_mut() {
                Some(ctx) => ctx.available().min(pool.available()),
                None => 0,
            };
            if cap == 0 {
                break;
            }
            let take = queue.len().min(cap);
            let mut chunk: Vec<ResumeState> = Vec::with_capacity(take);
            for _ in 0..take {
                if let Some(r) = queue.pop_front() {
                    chunk.push(r);
                }
            }
            let mut attempts = 0u32;
            loop {
                attempts += 1;
                // lint: fault-site(swap-readmit)
                let waved = crate::faults::inject(crate::faults::Site::SwapReadmit).and_then(
                    |()| match batched.as_mut() {
                        Some(ctx) => {
                            let seqs: Vec<Vec<u32>> =
                                chunk.iter().map(|r| r.seq.clone()).collect();
                            self.decoder.admit_wave(ctx, seqs)
                        }
                        None => Err(crate::error::Error::msg("fused arenas unavailable")),
                    },
                );
                match waved {
                    Ok(sessions) => {
                        for (r, fresh) in chunk.into_iter().zip(sessions) {
                            self.adopt_resumed(batched, pool, tx, r, fresh, slot_cap, active);
                        }
                        break;
                    }
                    Err(we) => {
                        // One bounded retry (admit_wave released its
                        // lanes), then fail the chunk per-request.
                        if attempts < 2 {
                            continue;
                        }
                        for r in chunk {
                            let resp = Self::resume_error(
                                &r,
                                format!("resume re-prefill failed: {we}"),
                            );
                            self.terminal(tx, &r.events, r.prompt_len, resp);
                        }
                        break;
                    }
                }
            }
        }
        // Per-sequence fallback: pre-batched bundles (or capacity beyond
        // the arenas) re-prefill into owned state, like admission does.
        while let Some(r) = queue.pop_front() {
            if pool.available() == 0 {
                let resp = Self::resume_error(
                    &r,
                    "resume re-admission failed: no slot capacity".to_string(),
                );
                self.terminal(tx, &r.events, r.prompt_len, resp);
                continue;
            }
            match self.decoder.start(&r.seq) {
                Ok(fresh) => self.adopt_resumed(batched, pool, tx, r, fresh, slot_cap, active),
                Err(e) => {
                    let resp = Self::resume_error(&r, format!("resume re-prefill failed: {e}"));
                    self.terminal(tx, &r.events, r.prompt_len, resp);
                }
            }
        }
    }

    /// Transplant a resumed request's bookkeeping onto its freshly
    /// re-prefilled session and promote it to an active lane — the
    /// salvage transplant plus the cross-segment fields (streaming
    /// offset, latency clocks, tag re-interning).
    #[allow(clippy::too_many_arguments)]
    fn adopt_resumed(
        &self,
        batched: &mut Option<crate::spec::BatchedCtx>,
        pool: &mut SlotPool<u64>,
        tx: &Sender<Response>,
        r: ResumeState,
        mut fresh: SpecSession,
        slot_cap: usize,
        active: &mut Vec<Active>,
    ) {
        let slot = match Self::claim_slot(pool, r.id, slot_cap, r.seq.len()) {
            Ok(slot) => slot,
            Err(e) => {
                self.release_lanes(batched, &mut fresh);
                let resp = Self::resume_error(&r, e.to_string());
                self.terminal(tx, &r.events, r.prompt_len, resp);
                return;
            }
        };
        fresh.prompt_len = r.prompt_len;
        fresh.trace_id = r.id;
        fresh.capture = r.capture;
        let mut stats = r.stats;
        stats.merge(&fresh.stats);
        fresh.stats = stats;
        let tag_slot = match (&self.telemetry, &r.tag) {
            (Some(tl), Some(tag)) => tl.intern(tag),
            _ => 0,
        };
        let mut depth_counts = r.depth_counts;
        depth_counts.resize(self.cfg.gamma + 1, 0);
        active.push(Active {
            id: r.id,
            session: fresh,
            sampling: r.sampling,
            max_new: r.max_new.min(self.cfg.max_new_tokens),
            rng: r.rng,
            enqueued: r.enqueued,
            first_token: r.first_token,
            deadline_at: r.deadline_at,
            events: r.events,
            streamed: r.streamed,
            slot,
            depth_counts,
            tag_slot,
            last_emit: r.last_emit,
            itl: r.itl,
            salvages: r.salvages,
            clean_blocks: r.clean_blocks,
            tag: r.tag,
        });
    }

    /// Terminal [`Response`] for a resident that could not be re-admitted
    /// into a fresh segment: delivered tokens preserved, error attached.
    fn resume_error(r: &ResumeState, error: String) -> Response {
        let mut tokens = r.seq[r.prompt_len..].to_vec();
        tokens.truncate(r.max_new);
        let mut stats = r.stats;
        stats.clip_to_delivered(tokens.len());
        let latency = r.enqueued.elapsed().as_secs_f64();
        let mut itl = r.itl.clone();
        itl.truncate(tokens.len().saturating_sub(1));
        Response {
            id: r.id,
            tokens,
            stats,
            latency,
            ttft: r.first_token.unwrap_or(latency),
            error: Some(error),
            depth_counts: r.depth_counts.clone(),
            itl,
        }
    }

    /// Pure decay rule for the salvage counter: after `reset_after`
    /// consecutive clean blocks a request's salvage history is forgiven.
    /// `reset_after == 0` keeps the pre-lifecycle behaviour (never).
    fn salvage_decay(salvages: u32, clean_blocks: u32, reset_after: u32) -> u32 {
        if reset_after > 0 && salvages > 0 && clean_blocks >= reset_after {
            0
        } else {
            salvages
        }
    }

    /// Lane salvage: a fused dispatch failure quarantined these requests —
    /// device state untrusted, host sequence intact, RNG rewound to the
    /// block start ([`crate::batch::LaneOutcome::Suspect`]). Their arena
    /// lanes go back to the free lists (the pool slot is KEPT: the
    /// request stays admitted), then every suspect sequence
    /// (prompt ++ emitted tokens) is re-prefilled in ONE admission wave
    /// and generation resumes mid-stream: streaming offsets, stats,
    /// capture and the acceptance-depth counts all carry over, so
    /// clients see no duplicated or lost deltas and `terminal()` still
    /// fires exactly once per request. Each wave attempt burns one of a
    /// request's [`SALVAGE_CAP`] tries; requests over the cap fail
    /// terminally with the quarantine error.
    fn salvage(
        &self,
        batched: &mut Option<crate::spec::BatchedCtx>,
        pool: &mut SlotPool<u64>,
        tx: &Sender<Response>,
        suspects: Vec<(Active, crate::error::Error)>,
        active: &mut Vec<Active>,
    ) -> Result<()> {
        let mut members: Vec<(Active, crate::error::Error)> = Vec::with_capacity(suspects.len());
        for (mut a, e) in suspects {
            self.release_lanes(batched, &mut a.session);
            members.push((a, e));
        }
        while !members.is_empty() {
            let mut ready: Vec<(Active, crate::error::Error)> = Vec::with_capacity(members.len());
            for (a, e) in members {
                if a.salvages >= SALVAGE_CAP {
                    pool.free(a.slot)?;
                    let resp =
                        Self::terminal_response(&a, Some(format!("lane salvage exhausted: {e}")));
                    self.terminal(tx, &a.events, a.session.prompt_len, resp);
                } else {
                    ready.push((a, e));
                }
            }
            if ready.is_empty() {
                return Ok(());
            }
            let Some(ctx) = batched.as_mut() else {
                // Unreachable (suspects only arise from fused dispatch),
                // kept defensive: without arenas there is nothing to
                // re-prefill into.
                for (a, e) in ready {
                    pool.free(a.slot)?;
                    let resp = Self::terminal_response(&a, Some(e.to_string()));
                    self.terminal(tx, &a.events, a.session.prompt_len, resp);
                }
                return Ok(());
            };
            for (a, _) in ready.iter_mut() {
                a.salvages += 1;
                // A salvage interrupts the clean-block run that would
                // otherwise forgive earlier salvages.
                a.clean_blocks = 0;
            }
            let seqs: Vec<Vec<u32>> = ready.iter().map(|(a, _)| a.session.seq.clone()).collect();
            match self.decoder.admit_wave(ctx, seqs) {
                Ok(sessions) => {
                    for ((mut a, _), mut fresh) in ready.into_iter().zip(sessions) {
                        // Transplant the request's bookkeeping onto the
                        // rebuilt session; decoding resumes exactly
                        // where the quarantined block started.
                        fresh.prompt_len = a.session.prompt_len;
                        fresh.trace_id = a.id;
                        fresh.capture = a.session.capture.take();
                        let mut stats = a.session.stats;
                        stats.merge(&fresh.stats);
                        fresh.stats = stats;
                        crate::faults::add_salvaged(1);
                        crate::trace::salvage(a.id, fresh.seq.len() as u64);
                        a.session = fresh;
                        active.push(a);
                    }
                    return Ok(());
                }
                Err(we) => {
                    // admit_wave released every wave lane on failure.
                    // Burn the try and retry the survivors with the
                    // fresher cause (the runtime retry layer already
                    // absorbed transient faults — this one persisted).
                    members = ready;
                    for (_, e) in members.iter_mut() {
                        *e = crate::error::Error::msg(format!("salvage re-prefill failed: {we}"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Allocate a pool slot for a freshly prefilled session and mirror its
    /// prompt length. A pool error here is a PER-REQUEST failure: the
    /// half-claimed slot is freed and the error returned for the caller to
    /// report on that request's channel — it must never propagate out of
    /// the scheduler loop (which would kill the thread and leak the
    /// already-prefilled lanes of every other in-flight request).
    fn claim_slot(
        pool: &mut SlotPool<u64>,
        id: u64,
        slot_cap: usize,
        prompt_len: usize,
    ) -> Result<SlotId> {
        let slot = pool.alloc(id, slot_cap)?;
        if let Err(e) = pool.get_mut(slot).and_then(|c| c.advance(prompt_len)) {
            let _ = pool.free(slot);
            return Err(e);
        }
        Ok(slot)
    }

    /// Intern the request's telemetry tag (slot 0 when untagged or when
    /// telemetry is off). Once per request, at admission.
    fn intern_tag(&self, req: &Request) -> u16 {
        match (&self.telemetry, &req.tag) {
            (Some(tl), Some(tag)) => tl.intern(tag),
            _ => 0,
        }
    }

    /// Promote an admitted (prefilled, slot-claimed) request to an active
    /// scheduler lane.
    fn make_active(&self, p: Pending, mut session: SpecSession, slot: SlotId) -> Active {
        // Thread the request ID into the engine so per-block trace instants
        // ([`crate::trace::req_block`]) attribute to this request.
        session.trace_id = p.req.id;
        let tag_slot = self.intern_tag(&p.req);
        Active {
            id: p.req.id,
            session,
            sampling: p.req.sampling,
            // Engine-side ceiling: the configured budget bounds every
            // admitted request (the HTTP edge clamps too).
            max_new: p.req.max_new.min(self.cfg.max_new_tokens),
            rng: Pcg64::with_stream(p.req.sampling.seed ^ p.req.id, 0x5e0e),
            enqueued: p.enqueued,
            first_token: None,
            deadline_at: p.deadline_at,
            events: p.req.events,
            streamed: 0,
            slot,
            depth_counts: vec![0; self.cfg.gamma + 1],
            tag_slot,
            last_emit: None,
            itl: Vec::new(),
            salvages: 0,
            clean_blocks: 0,
            tag: p.req.tag,
        }
    }

    /// Terminal [`Response`] for a request that failed (or was rejected)
    /// before owning a session.
    fn pending_error(p: &Pending, error: String) -> Response {
        let latency = p.enqueued.elapsed().as_secs_f64();
        Response {
            id: p.req.id,
            tokens: Vec::new(),
            stats: Default::default(),
            latency,
            ttft: latency,
            error: Some(error),
            depth_counts: Vec::new(),
            itl: Vec::new(),
        }
    }

    /// Build the terminal [`Response`] for `a`: tokens truncated to the
    /// budget, stats clipped to the delivered count, TTFT falling back to
    /// the full latency when nothing was emitted.
    fn terminal_response(a: &Active, error: Option<String>) -> Response {
        let mut tokens = a.session.generated().to_vec();
        tokens.truncate(a.max_new);
        let mut stats = a.session.stats;
        stats.clip_to_delivered(tokens.len());
        let latency = a.enqueued.elapsed().as_secs_f64();
        // Gaps beyond the delivered tokens (clipped bonus emissions) are
        // dropped: at most one gap per delivered token after the first.
        let mut itl = a.itl.clone();
        itl.truncate(tokens.len().saturating_sub(1));
        Response {
            id: a.id,
            tokens,
            stats,
            latency,
            ttft: a.first_token.unwrap_or(latency),
            error,
            depth_counts: a.depth_counts.clone(),
            itl,
        }
    }

    /// The single terminal choke point: EVERY request exit — success,
    /// deadline eviction, disconnect cancellation, validation/pool/wave
    /// error — flows through here exactly once, so the trace terminal,
    /// the access-log line, the `Delta::Done` and the response-channel
    /// send cannot drift apart (pinned by
    /// `one_terminal_per_request_across_exits` in
    /// rust/tests/coordinator_integration.rs).
    fn terminal(
        &self,
        tx: &Sender<Response>,
        events: &Option<Sender<Delta>>,
        tokens_in: usize,
        resp: Response,
    ) {
        // The lifecycle registry tracks only live requests; a terminated
        // request must never be re-admitted after a scheduler restart.
        if let Some(lc) = &self.lifecycle {
            lc.unregister(resp.id);
        }
        let reason = crate::trace::Reason::from_error(resp.error.as_deref());
        crate::trace::req_terminal(resp.id, reason, resp.tokens.len() as u64);
        if self.log_requests {
            let accept_rate = if resp.stats.drafted > 0 {
                resp.stats.accepted as f64 / resp.stats.drafted as f64
            } else {
                0.0
            };
            crate::trace::access_log(&crate::trace::AccessRecord {
                id: resp.id,
                status: reason.status(),
                tokens_in,
                tokens_out: resp.tokens.len(),
                ttft_s: resp.ttft,
                latency_s: resp.latency,
                accept_rate,
                reason: reason.name(),
            });
        }
        // A hung-up delta receiver makes this send fail, which is exactly
        // the disconnect case — the error is deliberately ignored on every
        // path rather than special-casing cancellations.
        if let Some(ev) = events {
            let _ = ev.send(Delta::Done(resp.clone()));
        }
        let _ = tx.send(resp);
    }

    /// Successful completion: fold into the aggregate and emit.
    fn finish(&self, metrics: &mut ServeMetrics, tx: &Sender<Response>, a: &Active) {
        let resp = Self::terminal_response(a, None);
        metrics.total_requests += 1;
        metrics.total_new_tokens += resp.tokens.len();
        metrics.request_latency.push(resp.latency);
        metrics.ttft.push(resp.ttft);
        metrics.ttft_hist.observe(resp.ttft);
        metrics.itl.extend_from_slice(&resp.itl);
        for &gap in &resp.itl {
            metrics.itl_hist.observe(gap);
        }
        metrics.spec.merge(&resp.stats);
        self.terminal(tx, &a.events, a.session.prompt_len, resp);
    }
}

/// Terminal for a request stranded by a scheduler failure the supervisor
/// could not absorb: delivered tokens are preserved, the error names the
/// cause, and BOTH the per-request delta stream and the response channel
/// observe exactly one terminal. Called by [`crate::lifecycle`] outside
/// any [`Coordinator`] (the panicked segment's coordinator is gone), so
/// it cannot route through [`Coordinator::terminal`]; the one-terminal
/// lint tracks it as a second chokepoint.
pub fn strand_terminal(tx: &Sender<Response>, r: &ResumeState, error: &str) {
    let mut tokens = r.seq[r.prompt_len.min(r.seq.len())..].to_vec();
    tokens.truncate(r.max_new);
    let mut stats = r.stats;
    stats.clip_to_delivered(tokens.len());
    let latency = r.enqueued.elapsed().as_secs_f64();
    let mut itl = r.itl.clone();
    itl.truncate(tokens.len().saturating_sub(1));
    let resp = Response {
        id: r.id,
        tokens,
        stats,
        latency,
        ttft: r.first_token.unwrap_or(latency),
        error: Some(error.to_string()),
        depth_counts: r.depth_counts.clone(),
        itl,
    };
    let reason = crate::trace::Reason::from_error(resp.error.as_deref());
    crate::trace::req_terminal(resp.id, reason, resp.tokens.len() as u64);
    if let Some(ev) = &r.events {
        let _ = ev.send(Delta::Done(resp.clone()));
    }
    let _ = tx.send(resp);
}

#[cfg(test)]
mod tests {
    // The coordinator requires compiled artifacts; its end-to-end behaviour
    // (all admitted requests terminate, pool-bounded batching, deferral,
    // starvation freedom, streaming deltas, deadline eviction, disconnect
    // cancellation) is covered in rust/tests/coordinator_integration.rs and
    // rust/tests/server_integration.rs. Pure scheduling invariants that
    // don't need models are tested via the exec channel tests and the
    // kvcache pool property tests.
    use super::*;

    #[test]
    fn request_new_defaults() {
        let r = Request::new(7, vec![1, 2], 16, SamplingConfig::greedy());
        assert!(r.deadline.is_none() && r.submitted.is_none() && r.events.is_none());
        assert_eq!(r.id, 7);
    }

    /// Regression (PR 5 satellite): a pool error while admitting one
    /// request must be a per-request failure. The old admission arm did
    /// `pool.alloc(..)?` / `pool.get_mut(..)?.advance(..)?`, so a pool
    /// error after a successful adopt killed the whole scheduler thread
    /// and leaked the adopted lane; `claim_slot` is the conversion point.
    #[test]
    fn claim_slot_pool_errors_are_per_request_and_leak_free() {
        let mut pool: SlotPool<u64> = SlotPool::new(1);
        // Prompt longer than the slot cap: error surfaces to the caller,
        // and the half-claimed slot is freed again (no leak).
        assert!(Coordinator::claim_slot(&mut pool, 7, 4, 10).is_err());
        assert_eq!(pool.live(), 0, "half-claimed slot must be freed");
        assert_eq!(pool.available(), 1);
        // A well-formed claim right after succeeds and mirrors the length.
        let slot = Coordinator::claim_slot(&mut pool, 7, 16, 10).unwrap();
        assert_eq!(pool.get(slot).unwrap().len(), 10);
        // Exhausted pool: error, existing slot untouched.
        assert!(Coordinator::claim_slot(&mut pool, 8, 16, 1).is_err());
        assert_eq!(pool.live(), 1);
        assert_eq!(pool.get(slot).unwrap().len(), 10);
    }

    /// Salvage forgiveness (PR 10 satellite): the eviction counter resets
    /// after a configurable run of clean blocks so one rough patch early
    /// in a long stream doesn't put the request one fault from eviction
    /// forever. `reset_after == 0` preserves the old never-reset policy.
    #[test]
    fn salvage_decay_resets_after_clean_run() {
        // Disabled: counter sticks no matter how clean the run.
        assert_eq!(Coordinator::salvage_decay(2, 1000, 0), 2);
        // Below the threshold: unchanged.
        assert_eq!(Coordinator::salvage_decay(2, 63, 64), 2);
        // At/above the threshold: forgiven.
        assert_eq!(Coordinator::salvage_decay(2, 64, 64), 0);
        assert_eq!(Coordinator::salvage_decay(1, 65, 64), 0);
        // Nothing to forgive stays nothing.
        assert_eq!(Coordinator::salvage_decay(0, 64, 64), 0);
    }
}

//! The serving coordinator: request queue, slot-pool admission control and
//! the batch-stepped scheduler loop.
//!
//! Architecture (vLLM-router-style, adapted to a single-device CPU PJRT
//! backend; with a batched bundle each lockstep phase below is ONE fused
//! `[B, T]` dispatch over a device-resident state arena, otherwise the
//! executables are dispatched per sequence):
//!
//! ```text
//!   clients ──bounded channel (backpressure)──▶ scheduler thread
//!                                              │ admit while the KV SlotPool
//!                                              │ has free slots (max_slots =
//!                                              │ the memory budget; exhausted
//!                                              │ pool defers, never errors)
//!                                              ▼
//!                                   one BatchStep per iteration:
//!                                     draft-sync sweep   (all lanes)
//!                                     proposal round j   (all lanes, j<γ)
//!                                     verify sweep       (all lanes)
//!                                              ▼
//!                                      responses channel ──▶ clients
//!                                      per-request delta channel ──▶ HTTP
//!                                      streaming handlers (optional)
//! ```
//!
//! PJRT handles are not `Send`, so the scheduler owns all model state on
//! one thread; concurrency with clients happens through the channels from
//! [`crate::exec`]. Phase-lockstep batching ([`crate::batch::BatchStep`])
//! bounds head-of-line blocking at one speculation block per sequence per
//! iteration and dispatches each phase's executable in one tight loop.
//!
//! Admission: [`crate::kvcache::SlotPool`] is the sole capacity gate. A
//! request is admitted exactly when a slot can be allocated; each slot
//! mirrors its sequence's length so `/metrics` can report resident KV
//! positions. When the pool is exhausted, queued requests wait (the
//! bounded channel provides backpressure further upstream).
//!
//! Streaming: a request may carry an `events` sender; the scheduler pushes
//! [`Delta::Started`] at admission, a [`Delta::Tokens`] after every
//! speculation block and a terminal [`Delta::Done`] mirroring the final
//! [`Response`]. The events channel is probed every iteration — a client
//! that hangs up is cancelled and frees its slot even when no tokens are
//! flowing toward it (exhausted `max_new` budget, capacity-finished
//! sequence), not just when the next delta send fails.
//!
//! Deadlines: a request may carry a wall-clock `deadline` measured from
//! `submitted` (or admission when unset). Expired sequences are evicted
//! with [`ERR_DEADLINE`] in `Response::error`, which the HTTP server maps
//! to `408 Request Timeout`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::batch::{BatchStep, Lane, LaneOutcome};
use crate::config::{RunConfig, SamplingConfig};
use crate::error::Result;
use crate::exec::{Receiver, Sender};
use crate::kvcache::{SlotId, SlotPool};
use crate::metrics::{SchedulerGauges, ServeMetrics};
use crate::rng::Pcg64;
use crate::spec::{SpecDecoder, SpecSession};

/// `Response::error` value for deadline-evicted requests (HTTP 408).
pub const ERR_DEADLINE: &str = "deadline exceeded";
/// `Response::error` value for client-disconnect cancellations.
pub const ERR_DISCONNECT: &str = "client disconnected";

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub sampling: SamplingConfig,
    /// Wall-clock budget measured from `submitted`; `None` = no limit.
    pub deadline: Option<Duration>,
    /// When the client enqueued the request (queue wait counts against the
    /// deadline and the reported latency); admission time when `None`.
    pub submitted: Option<Instant>,
    /// Incremental output sink: [`Delta::Started`] at admission, one
    /// [`Delta::Tokens`] per speculation block, then [`Delta::Done`]. The
    /// channel should be sized so the scheduler never blocks
    /// (`max_new + 3` suffices: every block emits at least one token).
    pub events: Option<Sender<Delta>>,
}

impl Request {
    /// A plain request with no deadline and no streaming sink.
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize, sampling: SamplingConfig) -> Request {
        Request { id, prompt, max_new, sampling, deadline: None, submitted: None, events: None }
    }
}

/// Incremental output event for one request (streaming mode).
#[derive(Debug, Clone)]
pub enum Delta {
    /// The request left the admission queue and owns a pool slot. Lets
    /// the HTTP layer distinguish a healthy-but-deep queue (no events
    /// yet) from a post-admission scheduler stall.
    Started,
    /// Tokens emitted by one speculation block, already clipped to the
    /// request's `max_new` budget.
    Tokens(Vec<u32>),
    /// Terminal event; mirrors the [`Response`] sent on the shared
    /// response channel (including the error cases).
    Done(Response),
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated tokens (prompt excluded), truncated to max_new.
    pub tokens: Vec<u32>,
    /// Engine counters, clipped to the delivered token count (so block
    /// efficiency describes what the client received).
    pub stats: crate::metrics::SpecStats,
    /// Queue + decode latency, seconds.
    pub latency: f64,
    /// Time to first emitted token, seconds. Equals `latency` when the
    /// request terminated (deadline, error, cancel) before emitting
    /// anything — never 0.0, which would poison windowed percentiles.
    pub ttft: f64,
    /// Error message when generation failed.
    pub error: Option<String>,
}

struct Active {
    id: u64,
    session: SpecSession,
    sampling: SamplingConfig,
    max_new: usize,
    rng: Pcg64,
    enqueued: Instant,
    first_token: Option<f64>,
    /// Absolute eviction deadline, when the request carries one.
    deadline_at: Option<Instant>,
    events: Option<Sender<Delta>>,
    /// Tokens already pushed through `events` (max_new clipping).
    streamed: usize,
    /// The KV pool slot this sequence occupies (freed on every exit path).
    slot: SlotId,
}

impl Active {
    fn expired(&self) -> bool {
        self.deadline_at.is_some_and(|d| Instant::now() >= d)
    }

    /// A streaming client whose receiver hung up. Probed every iteration:
    /// detection must not depend on a token send happening to fail.
    fn disconnected(&self) -> bool {
        self.events.as_ref().is_some_and(|ev| !ev.is_connected())
    }
}

/// The scheduler. Owns the models (via the decoder) for its lifetime.
pub struct Coordinator<'a> {
    decoder: SpecDecoder<'a>,
    cfg: RunConfig,
    gauges: Option<Arc<SchedulerGauges>>,
}

impl<'a> Coordinator<'a> {
    pub fn new(decoder: SpecDecoder<'a>, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Coordinator { decoder, cfg, gauges: None })
    }

    /// Attach live gauges (shared with the HTTP `/metrics` handler).
    pub fn with_gauges(mut self, gauges: Arc<SchedulerGauges>) -> Self {
        self.gauges = Some(gauges);
        self
    }

    /// Serve until the request channel closes and all work drains.
    /// Returns aggregate metrics.
    pub fn serve(&self, rx: Receiver<Request>, tx: Sender<Response>) -> Result<ServeMetrics> {
        let mut metrics = ServeMetrics::default();
        // Fused-dispatch arenas, when the bundle exports batched entry
        // points. Admitted sessions are adopted into them (arena-capacity
        // permitting) so every lockstep phase is one PJRT dispatch;
        // un-adopted sessions run per-lane within the same batch step.
        let mut batched = self.decoder.batched_ctx()?;
        // Slot capacity: the sequence mirror can exceed the processed
        // positions by exactly one — the final bonus token is appended to
        // the sequence but never reprocessed.
        let slot_cap = self.decoder.target.max_seq() + 1;
        let mut pool: SlotPool<u64> = SlotPool::new(self.cfg.max_slots);
        if let Some(g) = &self.gauges {
            g.pool_max.store(pool.max_slots(), Ordering::Relaxed);
        }
        let mut active: Vec<Active> = Vec::new();
        let mut rx_open = true;
        let wall0 = Instant::now();

        loop {
            // --- admission: allocate pool slots to queued requests -------
            while rx_open && pool.available() > 0 {
                let req = if active.is_empty() {
                    // Idle: block for work (or shutdown).
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            rx_open = false;
                            None
                        }
                    }
                } else {
                    rx.try_recv()
                };
                let Some(req) = req else { break };
                let enqueued = req.submitted.unwrap_or_else(Instant::now);
                let deadline_at = req.deadline.map(|d| enqueued + d);
                // Expired while queued: reject without spending a prefill.
                if deadline_at.is_some_and(|d| Instant::now() >= d) {
                    metrics.timeouts += 1;
                    let latency = enqueued.elapsed().as_secs_f64();
                    Self::emit(
                        &tx,
                        &req.events,
                        Response {
                            id: req.id,
                            tokens: Vec::new(),
                            stats: Default::default(),
                            latency,
                            ttft: latency,
                            error: Some(ERR_DEADLINE.to_string()),
                        },
                    );
                    continue;
                }
                // Hung up while queued: cancel before spending the prefill
                // (the most expensive per-request call) or a pool slot.
                if req.events.as_ref().is_some_and(|ev| !ev.is_connected()) {
                    metrics.cancelled += 1;
                    let latency = enqueued.elapsed().as_secs_f64();
                    let _ = tx.send(Response {
                        id: req.id,
                        tokens: Vec::new(),
                        stats: Default::default(),
                        latency,
                        ttft: latency,
                        error: Some(ERR_DISCONNECT.to_string()),
                    });
                    continue;
                }
                if let Some(ev) = &req.events {
                    let _ = ev.send(Delta::Started);
                }
                // Admission gather: prefill (owned state), then pack into
                // the fused arenas when there is lane capacity. An adopt
                // failure poisons only this session — report it like a
                // start failure.
                let started = self.decoder.start(&req.prompt).and_then(|mut session| {
                    if let Some(c) = batched.as_mut() {
                        if let Err(e) = self.decoder.adopt(c, &mut session) {
                            self.decoder.release(c, &mut session);
                            return Err(e);
                        }
                    }
                    Ok(session)
                });
                match started {
                    Ok(session) => {
                        let slot = pool.alloc(req.id, slot_cap)?;
                        pool.get_mut(slot)?.advance(session.prompt_len)?;
                        active.push(Active {
                            id: req.id,
                            session,
                            sampling: req.sampling,
                            // Engine-side ceiling: the configured budget
                            // bounds every admitted request (the HTTP edge
                            // clamps too).
                            max_new: req.max_new.min(self.cfg.max_new_tokens),
                            rng: Pcg64::with_stream(req.sampling.seed ^ req.id, 0x5e0e),
                            enqueued,
                            first_token: None,
                            deadline_at,
                            events: req.events,
                            streamed: 0,
                            slot,
                        });
                    }
                    Err(e) => {
                        Self::emit(
                            &tx,
                            &req.events,
                            Response {
                                id: req.id,
                                tokens: Vec::new(),
                                stats: Default::default(),
                                latency: enqueued.elapsed().as_secs_f64(),
                                ttft: enqueued.elapsed().as_secs_f64(),
                                error: Some(e.to_string()),
                            },
                        );
                    }
                }
            }
            // Pool exhausted with work still queued: defer admission until
            // a slot frees (the bounded request channel pushes back
            // further upstream) — never an error.
            if rx_open && pool.available() == 0 && !rx.is_empty() {
                metrics.admission_deferrals += 1;
                if let Some(g) = &self.gauges {
                    g.record_deferral();
                }
            }

            if active.is_empty() {
                if !rx_open {
                    break;
                }
                continue;
            }

            // --- eviction sweep: deadlines + disconnected clients --------
            let mut survivors = Vec::with_capacity(active.len());
            for mut a in active.drain(..) {
                if a.expired() {
                    metrics.timeouts += 1;
                    pool.free(a.slot)?;
                    self.release_lanes(&mut batched, &mut a.session);
                    Self::emit(
                        &tx,
                        &a.events,
                        Self::terminal_response(&a, Some(ERR_DEADLINE.to_string())),
                    );
                } else if a.disconnected() {
                    metrics.cancelled += 1;
                    pool.free(a.slot)?;
                    self.release_lanes(&mut batched, &mut a.session);
                    // The delta receiver is gone; only the shared response
                    // channel observes the cancellation.
                    let _ = tx.send(Self::terminal_response(&a, Some(ERR_DISCONNECT.to_string())));
                } else {
                    survivors.push(a);
                }
            }
            active = survivors;
            if active.is_empty() {
                continue;
            }

            // --- one scheduling iteration: a lockstep batch step ---------
            let (outcomes, timings) = {
                let mut lanes: Vec<Lane<'_>> = active
                    .iter_mut()
                    .map(|a| Lane {
                        session: &mut a.session,
                        sampling: a.sampling,
                        rng: &mut a.rng,
                    })
                    .collect();
                BatchStep::run(&self.decoder, batched.as_mut(), &mut lanes)
            };
            metrics.batch_iterations += 1;
            metrics.phase_draft_sync_seconds += timings.draft_sync;
            metrics.phase_propose_seconds += timings.propose;
            metrics.phase_verify_seconds += timings.verify;
            metrics.dispatches += timings.dispatches;
            metrics.lane_steps += timings.lanes;
            metrics.batched_lane_steps += timings.batched_lanes;

            let mut survivors = Vec::with_capacity(active.len());
            for (mut a, outcome) in active.drain(..).zip(outcomes) {
                match outcome {
                    LaneOutcome::Emitted(emitted) => {
                        pool.get_mut(a.slot)?.advance(emitted.len())?;
                        if a.first_token.is_none() {
                            a.first_token = Some(a.enqueued.elapsed().as_secs_f64());
                        }
                        // Stream the block's tokens, clipped to max_new.
                        let mut hung_up = false;
                        if let Some(ev) = &a.events {
                            let budget = a.max_new.saturating_sub(a.streamed);
                            let clip = emitted.len().min(budget);
                            if clip > 0 {
                                if ev.send(Delta::Tokens(emitted[..clip].to_vec())).is_err() {
                                    hung_up = true;
                                } else {
                                    a.streamed += clip;
                                }
                            }
                        }
                        if hung_up {
                            metrics.cancelled += 1;
                            pool.free(a.slot)?;
                            self.release_lanes(&mut batched, &mut a.session);
                            let _ = tx
                                .send(Self::terminal_response(&a, Some(ERR_DISCONNECT.to_string())));
                        } else if a.session.finished || a.session.generated().len() >= a.max_new {
                            pool.free(a.slot)?;
                            self.release_lanes(&mut batched, &mut a.session);
                            Self::finish(&mut metrics, &tx, &a);
                        } else {
                            survivors.push(a);
                        }
                    }
                    LaneOutcome::Idle => {
                        // Context capacity reached (the session is now
                        // finished): deliver the partial output as a
                        // successful completion.
                        pool.free(a.slot)?;
                        self.release_lanes(&mut batched, &mut a.session);
                        Self::finish(&mut metrics, &tx, &a);
                    }
                    LaneOutcome::Failed(e) => {
                        pool.free(a.slot)?;
                        self.release_lanes(&mut batched, &mut a.session);
                        Self::emit(&tx, &a.events, Self::terminal_response(&a, Some(e.to_string())));
                    }
                }
            }
            active = survivors;

            if let Some(g) = &self.gauges {
                g.pool_live.store(pool.live(), Ordering::Relaxed);
                g.pool_peak.store(pool.peak_live, Ordering::Relaxed);
                g.resident_tokens.store(pool.resident(), Ordering::Relaxed);
                g.queue_depth.store(rx.len(), Ordering::Relaxed);
                g.record_iteration(&timings);
            }
        }
        metrics.pool_peak_slots = pool.peak_live;
        metrics.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(metrics)
    }

    /// Return any fused-arena lanes a departing session holds (next to
    /// every `pool.free` — the slot pool and the arenas recycle together).
    fn release_lanes(
        &self,
        batched: &mut Option<crate::spec::BatchedCtx>,
        session: &mut SpecSession,
    ) {
        if let Some(c) = batched.as_mut() {
            self.decoder.release(c, session);
        }
    }

    /// Build the terminal [`Response`] for `a`: tokens truncated to the
    /// budget, stats clipped to the delivered count, TTFT falling back to
    /// the full latency when nothing was emitted.
    fn terminal_response(a: &Active, error: Option<String>) -> Response {
        let mut tokens = a.session.generated().to_vec();
        tokens.truncate(a.max_new);
        let mut stats = a.session.stats;
        stats.clip_to_delivered(tokens.len());
        let latency = a.enqueued.elapsed().as_secs_f64();
        Response { id: a.id, tokens, stats, latency, ttft: a.first_token.unwrap_or(latency), error }
    }

    /// Send a terminal on both the shared response channel and the
    /// request's delta sink (when present).
    fn emit(tx: &Sender<Response>, events: &Option<Sender<Delta>>, resp: Response) {
        if let Some(ev) = events {
            let _ = ev.send(Delta::Done(resp.clone()));
        }
        let _ = tx.send(resp);
    }

    /// Successful completion: fold into the aggregate and emit.
    fn finish(metrics: &mut ServeMetrics, tx: &Sender<Response>, a: &Active) {
        let resp = Self::terminal_response(a, None);
        metrics.total_requests += 1;
        metrics.total_new_tokens += resp.tokens.len();
        metrics.request_latency.push(resp.latency);
        metrics.ttft.push(resp.ttft);
        metrics.spec.merge(&resp.stats);
        Self::emit(tx, &a.events, resp);
    }
}

#[cfg(test)]
mod tests {
    // The coordinator requires compiled artifacts; its end-to-end behaviour
    // (all admitted requests terminate, pool-bounded batching, deferral,
    // starvation freedom, streaming deltas, deadline eviction, disconnect
    // cancellation) is covered in rust/tests/coordinator_integration.rs and
    // rust/tests/server_integration.rs. Pure scheduling invariants that
    // don't need models are tested via the exec channel tests and the
    // kvcache pool property tests.
    use super::*;

    #[test]
    fn request_new_defaults() {
        let r = Request::new(7, vec![1, 2], 16, SamplingConfig::greedy());
        assert!(r.deadline.is_none() && r.submitted.is_none() && r.events.is_none());
        assert_eq!(r.id, 7);
    }
}

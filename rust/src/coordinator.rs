//! The serving coordinator: request queue, admission control, continuous
//! (iteration-level) batching and the scheduler loop.
//!
//! Architecture (vLLM-router-style, adapted to a single-device CPU PJRT
//! backend whose executables are single-sequence):
//!
//! ```text
//!   clients ──bounded channel (backpressure)──▶ scheduler thread
//!                                              │ admit while slots free
//!                                              │ round-robin: one SD block
//!                                              │ per active sequence per
//!                                              │ iteration (continuous
//!                                              │ batching at block level)
//!                                              ▼
//!                                      responses channel ──▶ clients
//! ```
//!
//! PJRT handles are not `Send`, so the scheduler owns all model state on
//! one thread; concurrency with clients happens through the channels from
//! [`crate::exec`]. Iteration-level interleaving bounds head-of-line
//! blocking at one speculation block (γ+1 tokens) rather than one request.

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::{RunConfig, SamplingConfig};
use crate::error::Result;
use crate::exec::{Receiver, Sender};
use crate::metrics::ServeMetrics;
use crate::rng::Pcg64;
use crate::spec::{SpecDecoder, SpecSession};

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub sampling: SamplingConfig,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated tokens (prompt excluded), truncated to max_new.
    pub tokens: Vec<u32>,
    pub stats: crate::metrics::SpecStats,
    /// Queue + decode latency, seconds.
    pub latency: f64,
    /// Time to first emitted token, seconds.
    pub ttft: f64,
    /// Error message when generation failed.
    pub error: Option<String>,
}

struct Active {
    id: u64,
    session: SpecSession,
    sampling: SamplingConfig,
    max_new: usize,
    rng: Pcg64,
    enqueued: Instant,
    started: Instant,
    first_token: Option<f64>,
}

/// The scheduler. Owns the models (via the decoder) for its lifetime.
pub struct Coordinator<'a> {
    decoder: SpecDecoder<'a>,
    cfg: RunConfig,
}

impl<'a> Coordinator<'a> {
    pub fn new(decoder: SpecDecoder<'a>, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Coordinator { decoder, cfg })
    }

    /// Serve until the request channel closes and all work drains.
    /// Returns aggregate metrics.
    pub fn serve(&self, rx: Receiver<Request>, tx: Sender<Response>) -> Result<ServeMetrics> {
        let mut metrics = ServeMetrics::default();
        let mut active: VecDeque<Active> = VecDeque::new();
        let mut rx_open = true;
        let wall0 = Instant::now();

        loop {
            // --- admission: fill free slots ------------------------------
            while rx_open && active.len() < self.cfg.max_batch {
                let req = if active.is_empty() {
                    // Idle: block for work (or shutdown).
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            rx_open = false;
                            None
                        }
                    }
                } else {
                    rx.try_recv()
                };
                let Some(req) = req else { break };
                let enqueued = Instant::now();
                match self.decoder.start(&req.prompt) {
                    Ok(session) => active.push_back(Active {
                        id: req.id,
                        session,
                        sampling: req.sampling,
                        max_new: req.max_new.min(self.cfg.max_new_tokens.max(req.max_new)),
                        rng: Pcg64::with_stream(req.sampling.seed ^ req.id, 0x5e0e),
                        enqueued,
                        started: Instant::now(),
                        first_token: None,
                    }),
                    Err(e) => {
                        let _ = tx.send(Response {
                            id: req.id,
                            tokens: Vec::new(),
                            stats: Default::default(),
                            latency: 0.0,
                            ttft: 0.0,
                            error: Some(e.to_string()),
                        });
                    }
                }
            }

            if active.is_empty() {
                if !rx_open {
                    break;
                }
                continue;
            }

            // --- one scheduling iteration: one block per active sequence --
            let mut still_active = VecDeque::with_capacity(active.len());
            while let Some(mut a) = active.pop_front() {
                let step = self.decoder.step(&mut a.session, &a.sampling, &mut a.rng);
                match step {
                    Ok(emitted) => {
                        if !emitted.is_empty() && a.first_token.is_none() {
                            a.first_token = Some(a.enqueued.elapsed().as_secs_f64());
                        }
                        let done = a.session.finished
                            || a.session.generated().len() >= a.max_new
                            || emitted.is_empty();
                        if done {
                            self.finish(&mut metrics, &tx, a)?;
                        } else {
                            still_active.push_back(a);
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Response {
                            id: a.id,
                            tokens: a.session.generated().to_vec(),
                            stats: a.session.stats,
                            latency: a.enqueued.elapsed().as_secs_f64(),
                            ttft: a.first_token.unwrap_or(0.0),
                            error: Some(e.to_string()),
                        });
                    }
                }
            }
            active = still_active;
        }
        metrics.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(metrics)
    }

    fn finish(
        &self,
        metrics: &mut ServeMetrics,
        tx: &Sender<Response>,
        a: Active,
    ) -> Result<()> {
        let mut tokens = a.session.generated().to_vec();
        tokens.truncate(a.max_new);
        let latency = a.enqueued.elapsed().as_secs_f64();
        metrics.total_requests += 1;
        metrics.total_new_tokens += tokens.len();
        metrics.request_latency.push(latency);
        metrics.ttft.push(a.first_token.unwrap_or(latency));
        metrics.spec.merge(&a.session.stats);
        let _ = tx.send(Response {
            id: a.id,
            tokens,
            stats: a.session.stats,
            latency,
            ttft: a.first_token.unwrap_or(latency),
            error: None,
        });
        let _ = a.started; // reserved for decode-only latency metrics
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // The coordinator requires compiled artifacts; its end-to-end behaviour
    // (all admitted requests terminate, batching bounds, starvation freedom)
    // is covered in rust/tests/coordinator_integration.rs. Pure scheduling
    // invariants that don't need models are tested via the exec channel
    // tests and the kvcache pool property tests.
}

//! The serving coordinator: request queue, admission control, continuous
//! (iteration-level) batching and the scheduler loop.
//!
//! Architecture (vLLM-router-style, adapted to a single-device CPU PJRT
//! backend whose executables are single-sequence):
//!
//! ```text
//!   clients ──bounded channel (backpressure)──▶ scheduler thread
//!                                              │ admit while slots free
//!                                              │ round-robin: one SD block
//!                                              │ per active sequence per
//!                                              │ iteration (continuous
//!                                              │ batching at block level)
//!                                              ▼
//!                                      responses channel ──▶ clients
//!                                      per-request delta channel ──▶ HTTP
//!                                      streaming handlers (optional)
//! ```
//!
//! PJRT handles are not `Send`, so the scheduler owns all model state on
//! one thread; concurrency with clients happens through the channels from
//! [`crate::exec`]. Iteration-level interleaving bounds head-of-line
//! blocking at one speculation block (γ+1 tokens) rather than one request.
//!
//! Streaming: a request may carry an `events` sender; the scheduler pushes
//! [`Delta::Started`] at admission, a [`Delta::Tokens`] after every
//! speculation block and a terminal [`Delta::Done`] mirroring the final
//! [`Response`]. When the receiving side hangs up (HTTP client
//! disconnect) the sequence is cancelled and its slot freed immediately.
//!
//! Deadlines: a request may carry a wall-clock `deadline` measured from
//! `submitted` (or admission when unset). Expired sequences are evicted
//! with [`ERR_DEADLINE`] in `Response::error`, which the HTTP server maps
//! to `408 Request Timeout`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::config::{RunConfig, SamplingConfig};
use crate::error::Result;
use crate::exec::{Receiver, Sender};
use crate::metrics::ServeMetrics;
use crate::rng::Pcg64;
use crate::spec::{SpecDecoder, SpecSession};

/// `Response::error` value for deadline-evicted requests (HTTP 408).
pub const ERR_DEADLINE: &str = "deadline exceeded";
/// `Response::error` value for client-disconnect cancellations.
pub const ERR_DISCONNECT: &str = "client disconnected";

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub sampling: SamplingConfig,
    /// Wall-clock budget measured from `submitted`; `None` = no limit.
    pub deadline: Option<Duration>,
    /// When the client enqueued the request (queue wait counts against the
    /// deadline and the reported latency); admission time when `None`.
    pub submitted: Option<Instant>,
    /// Incremental output sink: [`Delta::Started`] at admission, one
    /// [`Delta::Tokens`] per speculation block, then [`Delta::Done`]. The
    /// channel should be sized so the scheduler never blocks
    /// (`max_new + 3` suffices: every block emits at least one token).
    pub events: Option<Sender<Delta>>,
}

impl Request {
    /// A plain request with no deadline and no streaming sink.
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize, sampling: SamplingConfig) -> Request {
        Request { id, prompt, max_new, sampling, deadline: None, submitted: None, events: None }
    }
}

/// Incremental output event for one request (streaming mode).
#[derive(Debug, Clone)]
pub enum Delta {
    /// The request left the admission queue and owns a batch slot. Lets
    /// the HTTP layer distinguish a healthy-but-deep queue (no events
    /// yet) from a post-admission scheduler stall.
    Started,
    /// Tokens emitted by one speculation block, already clipped to the
    /// request's `max_new` budget.
    Tokens(Vec<u32>),
    /// Terminal event; mirrors the [`Response`] sent on the shared
    /// response channel (including the error cases).
    Done(Response),
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated tokens (prompt excluded), truncated to max_new.
    pub tokens: Vec<u32>,
    pub stats: crate::metrics::SpecStats,
    /// Queue + decode latency, seconds.
    pub latency: f64,
    /// Time to first emitted token, seconds.
    pub ttft: f64,
    /// Error message when generation failed.
    pub error: Option<String>,
}

struct Active {
    id: u64,
    session: SpecSession,
    sampling: SamplingConfig,
    max_new: usize,
    rng: Pcg64,
    enqueued: Instant,
    started: Instant,
    first_token: Option<f64>,
    /// Absolute eviction deadline, when the request carries one.
    deadline_at: Option<Instant>,
    events: Option<Sender<Delta>>,
    /// Tokens already pushed through `events` (max_new clipping).
    streamed: usize,
}

impl Active {
    fn expired(&self) -> bool {
        self.deadline_at.is_some_and(|d| Instant::now() >= d)
    }
}

/// The scheduler. Owns the models (via the decoder) for its lifetime.
pub struct Coordinator<'a> {
    decoder: SpecDecoder<'a>,
    cfg: RunConfig,
}

impl<'a> Coordinator<'a> {
    pub fn new(decoder: SpecDecoder<'a>, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Coordinator { decoder, cfg })
    }

    /// Serve until the request channel closes and all work drains.
    /// Returns aggregate metrics.
    pub fn serve(&self, rx: Receiver<Request>, tx: Sender<Response>) -> Result<ServeMetrics> {
        let mut metrics = ServeMetrics::default();
        let mut active: VecDeque<Active> = VecDeque::new();
        let mut rx_open = true;
        let wall0 = Instant::now();

        loop {
            // --- admission: fill free slots ------------------------------
            while rx_open && active.len() < self.cfg.max_batch {
                let req = if active.is_empty() {
                    // Idle: block for work (or shutdown).
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            rx_open = false;
                            None
                        }
                    }
                } else {
                    rx.try_recv()
                };
                let Some(req) = req else { break };
                let enqueued = req.submitted.unwrap_or_else(Instant::now);
                let deadline_at = req.deadline.map(|d| enqueued + d);
                // Expired while queued: reject without spending a prefill.
                if deadline_at.is_some_and(|d| Instant::now() >= d) {
                    metrics.timeouts += 1;
                    Self::emit_error(
                        &tx,
                        &req.events,
                        req.id,
                        Vec::new(),
                        Default::default(),
                        enqueued.elapsed().as_secs_f64(),
                        0.0,
                        ERR_DEADLINE,
                    );
                    continue;
                }
                if let Some(ev) = &req.events {
                    let _ = ev.send(Delta::Started);
                }
                match self.decoder.start(&req.prompt) {
                    Ok(session) => active.push_back(Active {
                        id: req.id,
                        session,
                        sampling: req.sampling,
                        // Engine-side ceiling: the configured budget bounds
                        // every admitted request (the HTTP edge clamps too).
                        max_new: req.max_new.min(self.cfg.max_new_tokens),
                        rng: Pcg64::with_stream(req.sampling.seed ^ req.id, 0x5e0e),
                        enqueued,
                        started: Instant::now(),
                        first_token: None,
                        deadline_at,
                        events: req.events,
                        streamed: 0,
                    }),
                    Err(e) => {
                        Self::emit_error(
                            &tx,
                            &req.events,
                            req.id,
                            Vec::new(),
                            Default::default(),
                            0.0,
                            0.0,
                            &e.to_string(),
                        );
                    }
                }
            }

            if active.is_empty() {
                if !rx_open {
                    break;
                }
                continue;
            }

            // --- one scheduling iteration: one block per active sequence --
            let mut still_active = VecDeque::with_capacity(active.len());
            while let Some(mut a) = active.pop_front() {
                // Deadline eviction: free the slot, report partial output.
                if a.expired() {
                    metrics.timeouts += 1;
                    let mut tokens = a.session.generated().to_vec();
                    tokens.truncate(a.max_new);
                    Self::emit_error(
                        &tx,
                        &a.events,
                        a.id,
                        tokens,
                        a.session.stats,
                        a.enqueued.elapsed().as_secs_f64(),
                        a.first_token.unwrap_or(0.0),
                        ERR_DEADLINE,
                    );
                    continue;
                }
                let step = self.decoder.step(&mut a.session, &a.sampling, &mut a.rng);
                match step {
                    Ok(emitted) => {
                        if !emitted.is_empty() && a.first_token.is_none() {
                            a.first_token = Some(a.enqueued.elapsed().as_secs_f64());
                        }
                        // Stream the block's tokens, clipped to max_new.
                        if let Some(ev) = &a.events {
                            let budget = a.max_new.saturating_sub(a.streamed);
                            let clip = emitted.len().min(budget);
                            if clip > 0 && ev.send(Delta::Tokens(emitted[..clip].to_vec())).is_err()
                            {
                                // Client hung up: cancel, free the slot.
                                metrics.cancelled += 1;
                                let mut tokens = a.session.generated().to_vec();
                                tokens.truncate(a.max_new);
                                let _ = tx.send(Response {
                                    id: a.id,
                                    tokens,
                                    stats: a.session.stats,
                                    latency: a.enqueued.elapsed().as_secs_f64(),
                                    ttft: a.first_token.unwrap_or(0.0),
                                    error: Some(ERR_DISCONNECT.to_string()),
                                });
                                continue;
                            }
                            a.streamed += clip;
                        }
                        let done = a.session.finished
                            || a.session.generated().len() >= a.max_new
                            || emitted.is_empty();
                        if done {
                            self.finish(&mut metrics, &tx, a)?;
                        } else {
                            still_active.push_back(a);
                        }
                    }
                    Err(e) => {
                        let mut tokens = a.session.generated().to_vec();
                        tokens.truncate(a.max_new);
                        Self::emit_error(
                            &tx,
                            &a.events,
                            a.id,
                            tokens,
                            a.session.stats,
                            a.enqueued.elapsed().as_secs_f64(),
                            a.first_token.unwrap_or(0.0),
                            &e.to_string(),
                        );
                    }
                }
            }
            active = still_active;
        }
        metrics.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(metrics)
    }

    /// Send an error terminal on both the shared response channel and the
    /// request's delta sink (when present).
    #[allow(clippy::too_many_arguments)]
    fn emit_error(
        tx: &Sender<Response>,
        events: &Option<Sender<Delta>>,
        id: u64,
        tokens: Vec<u32>,
        stats: crate::metrics::SpecStats,
        latency: f64,
        ttft: f64,
        error: &str,
    ) {
        let resp = Response { id, tokens, stats, latency, ttft, error: Some(error.to_string()) };
        if let Some(ev) = events {
            let _ = ev.send(Delta::Done(resp.clone()));
        }
        let _ = tx.send(resp);
    }

    fn finish(
        &self,
        metrics: &mut ServeMetrics,
        tx: &Sender<Response>,
        a: Active,
    ) -> Result<()> {
        let mut tokens = a.session.generated().to_vec();
        tokens.truncate(a.max_new);
        let latency = a.enqueued.elapsed().as_secs_f64();
        metrics.total_requests += 1;
        metrics.total_new_tokens += tokens.len();
        metrics.request_latency.push(latency);
        metrics.ttft.push(a.first_token.unwrap_or(latency));
        metrics.spec.merge(&a.session.stats);
        let resp = Response {
            id: a.id,
            tokens,
            stats: a.session.stats,
            latency,
            ttft: a.first_token.unwrap_or(latency),
            error: None,
        };
        if let Some(ev) = &a.events {
            let _ = ev.send(Delta::Done(resp.clone()));
        }
        let _ = tx.send(resp);
        let _ = a.started; // reserved for decode-only latency metrics
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // The coordinator requires compiled artifacts; its end-to-end behaviour
    // (all admitted requests terminate, batching bounds, starvation
    // freedom, streaming deltas, deadline eviction) is covered in
    // rust/tests/coordinator_integration.rs and
    // rust/tests/server_integration.rs. Pure scheduling invariants that
    // don't need models are tested via the exec channel tests and the
    // kvcache pool property tests.
    use super::*;

    #[test]
    fn request_new_defaults() {
        let r = Request::new(7, vec![1, 2], 16, SamplingConfig::greedy());
        assert!(r.deadline.is_none() && r.submitted.is_none() && r.events.is_none());
        assert_eq!(r.id, 7);
    }
}

//! Batch-stepped speculative scheduling: run each phase of the SD block
//! in lockstep across all active sequences.
//!
//! One [`BatchStep::run`] performs, over every lane:
//!
//! 1. a **draft-sync sweep** (one [`SpecDecoder::begin_block`] per lane),
//! 2. γ **proposal-round sweeps** — round j for *every* lane before round
//!    j+1 for any ([`SpecDecoder::propose_round`]),
//! 3. a **verify sweep** ([`SpecDecoder::commit_block`]).
//!
//! The point of the lockstep is dispatch locality: within a phase the same
//! PJRT executable is invoked back-to-back for all sequences, so the
//! scheduler is already shaped for genuinely batched executables — when
//! the compile pipeline exports `[B, T]` entry points, only the inner
//! loops here fuse into single calls; the coordinator above doesn't
//! change. Until then the win is instruction/weight locality and the
//! per-phase timing signal exported to `/metrics`.
//!
//! Correctness under interleaving: each lane owns a private RNG and the
//! per-lane order of RNG consumption (γ proposal samples, then the
//! verification draws) is identical to the single-sequence
//! [`SpecDecoder::step`], so batch-stepped output token-matches the
//! direct engine (pinned by `rust/tests/coordinator_integration.rs`).
//!
//! Two drivers sit on top: the latency-oriented [`crate::coordinator`]
//! (serving, deadlines, streaming) and the throughput-oriented
//! [`crate::datagen`] (`specd distill` saturation mode — no deadlines,
//! every slot kept full until a token budget is met).

use std::time::Instant;

use crate::config::SamplingConfig;
use crate::error::Error;
use crate::rng::Pcg64;
use crate::spec::{BlockState, SpecDecoder, SpecSession};

/// One active sequence's slice of the batch: mutable views the phases
/// need, borrowed from the coordinator's per-request state for the
/// duration of one step.
pub struct Lane<'s> {
    pub session: &'s mut SpecSession,
    pub sampling: SamplingConfig,
    pub rng: &'s mut Pcg64,
}

/// Per-lane result of one batch step.
#[derive(Debug)]
pub enum LaneOutcome {
    /// The lane's block emitted these tokens (never empty).
    Emitted(Vec<u32>),
    /// No block ran: the sequence is at capacity (now marked finished) or
    /// was already finished.
    Idle,
    /// A phase failed; the sequence must be evicted.
    Failed(Error),
}

/// Wall-clock seconds spent in each lockstep phase of one batch step.
#[derive(Debug, Default, Clone, Copy)]
pub struct PhaseTimings {
    pub draft_sync: f64,
    pub propose: f64,
    pub verify: f64,
}

/// The lockstep executor (stateless; the state lives in the lanes).
pub struct BatchStep;

impl BatchStep {
    /// Run one speculation block for every lane, phase by phase. Always
    /// returns exactly one outcome per lane, in lane order.
    pub fn run(decoder: &SpecDecoder<'_>, lanes: &mut [Lane<'_>]) -> (Vec<LaneOutcome>, PhaseTimings) {
        let n = lanes.len();
        let mut timings = PhaseTimings::default();
        let mut outcomes: Vec<Option<LaneOutcome>> = (0..n).map(|_| None).collect();
        let mut blocks: Vec<Option<BlockState>> = (0..n).map(|_| None).collect();

        // Phase 1 — draft-sync sweep.
        let t0 = Instant::now();
        for (i, lane) in lanes.iter_mut().enumerate() {
            match decoder.begin_block(lane.session) {
                Ok(Some(b)) => blocks[i] = Some(b),
                Ok(None) => outcomes[i] = Some(LaneOutcome::Idle),
                Err(e) => outcomes[i] = Some(LaneOutcome::Failed(e)),
            }
        }
        timings.draft_sync = t0.elapsed().as_secs_f64();

        // Phase 2 — proposal round j across every lane still drafting.
        // Lanes near the context cap carry a shrunken per-block gamma and
        // simply sit out the later rounds.
        let t0 = Instant::now();
        let rounds = blocks.iter().flatten().map(|b| b.gamma()).max().unwrap_or(0);
        for _round in 0..rounds {
            for (i, lane) in lanes.iter_mut().enumerate() {
                let Some(b) = blocks[i].as_mut() else { continue };
                if b.proposed() >= b.gamma() {
                    continue;
                }
                if let Err(e) = decoder.propose_round(lane.session, b, &lane.sampling, lane.rng) {
                    outcomes[i] = Some(LaneOutcome::Failed(e));
                    blocks[i] = None;
                }
            }
        }
        timings.propose = t0.elapsed().as_secs_f64();

        // Phase 3 — verify sweep.
        let t0 = Instant::now();
        for (i, lane) in lanes.iter_mut().enumerate() {
            let Some(b) = blocks[i].take() else { continue };
            outcomes[i] =
                Some(match decoder.commit_block(lane.session, b, &lane.sampling, lane.rng) {
                    Ok(tokens) => LaneOutcome::Emitted(tokens),
                    Err(e) => LaneOutcome::Failed(e),
                });
        }
        timings.verify = t0.elapsed().as_secs_f64();

        let outcomes = outcomes
            .into_iter()
            .map(|o| o.expect("every lane resolves to an outcome"))
            .collect();
        (outcomes, timings)
    }
}

#[cfg(test)]
mod tests {
    // BatchStep needs live sessions (compiled artifacts); its end-to-end
    // behaviour — batched output == direct engine output, per-phase
    // lockstep, shrunken-gamma lanes sitting out late rounds — is covered
    // by rust/tests/coordinator_integration.rs. The phase-capacity
    // arithmetic is unit-tested in crate::spec (shrunken_gamma).
    use super::PhaseTimings;

    #[test]
    fn timings_default_zero() {
        let t = PhaseTimings::default();
        assert_eq!(t.draft_sync + t.propose + t.verify, 0.0);
    }
}

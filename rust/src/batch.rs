//! Batch-stepped speculative scheduling: run each phase of the SD block
//! in lockstep across all active sequences.
//!
//! One [`BatchStep::run`] performs, over every lane:
//!
//! 1. a **draft-sync sweep** (draft ingestion for every lane),
//! 2. γ **proposal-round sweeps** — round j for *every* lane before round
//!    j+1 for any,
//! 3. a **verify sweep**.
//!
//! With a [`BatchedCtx`] loaded (bundles exported with batched `[B, T]`
//! entry points), each phase over the adopted lanes is a SINGLE fused
//! PJRT dispatch ([`SpecDecoder::begin_block_batch`] /
//! [`SpecDecoder::propose_round_batch`] /
//! [`SpecDecoder::commit_block_batch`]): one `BatchStep::run` over N
//! lanes issues O(γ + 2) dispatches instead of O(N·(γ + 2)). Sessions
//! that could not be adopted (full arena, or a pre-batched bundle) fall
//! back to per-lane dispatch of the single-sequence phase methods within
//! the same lockstep — a mixed batch is correct, just less fused.
//!
//! Correctness under interleaving: each lane owns a private RNG and the
//! per-lane order of RNG consumption (γ proposal samples, then the
//! verification draws) is identical to the single-sequence
//! [`SpecDecoder::step`], so batch-stepped output token-matches the
//! direct engine in both modes (pinned by
//! `rust/tests/coordinator_integration.rs` and
//! `rust/tests/batched_integration.rs`).
//!
//! Two drivers sit on top: the latency-oriented [`crate::coordinator`]
//! (serving, deadlines, streaming) and the throughput-oriented
//! [`crate::datagen`] (`specd distill` saturation mode — no deadlines,
//! every slot kept full until a token budget is met).
//!
//! Admission runs AROUND the batch step, in the same fused regime: both
//! drivers refill free lanes through a [`crate::spec::PrefillWave`]
//! (chunk-lockstep batched prefill directly into arena lanes), and may
//! slice a wave across iterations by a prefill-token budget — so one
//! scheduler iteration is "≤ budget admission prefill tokens, then one
//! `BatchStep` over the residents". Wave chunk dispatches mask every
//! resident lane (state pass-through), which is why the interleaving
//! cannot perturb resident sequences (pinned by
//! `rust/tests/admission_integration.rs`).

use std::time::Instant;

use crate::config::SamplingConfig;
use crate::error::Error;
use crate::rng::Pcg64;
use crate::spec::{BatchedCtx, BlockState, SpecDecoder, SpecSession};

/// One active sequence's slice of the batch: mutable views the phases
/// need, borrowed from the coordinator's per-request state for the
/// duration of one step.
pub struct Lane<'s> {
    pub session: &'s mut SpecSession,
    pub sampling: SamplingConfig,
    pub rng: &'s mut Pcg64,
}

/// Per-lane result of one batch step.
#[derive(Debug)]
pub enum LaneOutcome {
    /// The lane's block emitted these tokens (never empty).
    Emitted(Vec<u32>),
    /// No block ran: the sequence is at capacity (now marked finished) or
    /// was already finished.
    Idle,
    /// A phase failed; the sequence must be evicted.
    Failed(Error),
    /// A shared fused dispatch failed mid-block: the lane's device state
    /// is no longer trusted, but its host-side sequence is intact and
    /// its RNG has been rewound to the block start. The driver should
    /// salvage it (release the arena lanes, re-prefill from the
    /// sequence, resume) instead of evicting — see
    /// [`crate::coordinator`]'s lane-salvage path.
    Suspect(Error),
}

/// Wall-clock seconds spent in each lockstep phase of one batch step,
/// plus the step's dispatch and occupancy accounting.
#[derive(Debug, Default, Clone, Copy)]
pub struct PhaseTimings {
    pub draft_sync: f64,
    pub propose: f64,
    pub verify: f64,
    /// PJRT executable launches issued during this step (draft + target;
    /// extract readbacks included). With the fused path this is O(γ + 2)
    /// per step; per-lane it is O(N·(γ + 2)).
    pub dispatches: u64,
    /// Lanes that emitted a block this step (the batch occupancy).
    pub lanes: usize,
    /// Of those, lanes served by fused batched dispatch.
    pub batched_lanes: usize,
}

/// The lockstep executor (stateless; the state lives in the lanes and the
/// optional arenas).
pub struct BatchStep;

impl BatchStep {
    /// Run one speculation block for every lane, phase by phase. Always
    /// returns exactly one outcome per lane, in lane order. `ctx` carries
    /// the fused-dispatch arenas; `None` (or an un-adopted session) means
    /// per-lane dispatch.
    pub fn run(
        decoder: &SpecDecoder<'_>,
        mut ctx: Option<&mut BatchedCtx>,
        lanes: &mut [Lane<'_>],
    ) -> (Vec<LaneOutcome>, PhaseTimings) {
        let n = lanes.len();
        let fused = ctx.is_some();
        let mut timings = PhaseTimings::default();
        let dispatches0 = decoder.dispatch_count();
        let mut blocks: Vec<Option<BlockState>> = (0..n).map(|_| None).collect();
        let mut failed: Vec<Option<Error>> = (0..n).map(|_| None).collect();
        let mut emitted: Vec<Option<Vec<u32>>> = (0..n).map(|_| None).collect();
        let mut suspect: Vec<Option<Error>> = (0..n).map(|_| None).collect();
        // RNG snapshots at the block start: a quarantined lane's RNG is
        // rewound so the salvaged re-run of this block draws the same
        // sample sequence as a fault-free run would have.
        let rng0: Vec<Pcg64> = if fused { lanes.iter().map(|l| l.rng.clone()).collect() } else {
            Vec::new()
        };
        // A lane runs fused iff its session was adopted into the arenas.
        let is_fused = |lane: &Lane<'_>| fused && lane.session.lane_mode();

        // Phase 1 — draft-sync sweep.
        let t0 = Instant::now();
        let tr0 = crate::trace::begin();
        if let Some(c) = ctx.as_deref_mut() {
            if let Err(e) = decoder.begin_block_batch(c, lanes, &mut blocks, &mut failed) {
                Self::quarantine_fused(lanes, &mut blocks, &emitted, &mut suspect, &rng0, &e);
            }
        }
        for (i, lane) in lanes.iter_mut().enumerate() {
            if is_fused(lane) || failed[i].is_some() {
                continue;
            }
            match decoder.begin_block(lane.session) {
                Ok(b) => blocks[i] = b,
                Err(e) => failed[i] = Some(e),
            }
        }
        timings.draft_sync = t0.elapsed().as_secs_f64();
        crate::trace::phase(tr0, crate::trace::Phase::DraftSync, n as u64);

        // Phase 2 — proposal round j across every lane still drafting.
        // Lanes near the context cap carry a shrunken per-block gamma and
        // simply sit out the later rounds.
        let t0 = Instant::now();
        let tr0 = crate::trace::begin();
        let rounds = blocks.iter().flatten().map(|b| b.gamma()).max().unwrap_or(0);
        for _round in 0..rounds {
            if let Some(c) = ctx.as_deref_mut() {
                if let Err(e) = decoder.propose_round_batch(c, lanes, &mut blocks, &mut failed) {
                    Self::quarantine_fused(lanes, &mut blocks, &emitted, &mut suspect, &rng0, &e);
                }
            }
            for (i, lane) in lanes.iter_mut().enumerate() {
                if is_fused(lane) || failed[i].is_some() {
                    continue;
                }
                let Some(b) = blocks[i].as_mut() else { continue };
                if b.proposed() >= b.gamma() {
                    continue;
                }
                if let Err(e) = decoder.propose_round(lane.session, b, &lane.sampling, lane.rng) {
                    failed[i] = Some(e);
                    blocks[i] = None;
                }
            }
        }
        timings.propose = t0.elapsed().as_secs_f64();
        crate::trace::phase(tr0, crate::trace::Phase::Propose, n as u64);

        // Phase 3 — verify sweep.
        let t0 = Instant::now();
        let tr0 = crate::trace::begin();
        if let Some(c) = ctx.as_deref_mut() {
            if let Err(e) =
                decoder.commit_block_batch(c, lanes, &mut blocks, &mut failed, &mut emitted)
            {
                Self::quarantine_fused(lanes, &mut blocks, &emitted, &mut suspect, &rng0, &e);
            }
        }
        for (i, lane) in lanes.iter_mut().enumerate() {
            if is_fused(lane) || failed[i].is_some() {
                continue;
            }
            let Some(b) = blocks[i].take() else { continue };
            match decoder.commit_block(lane.session, b, &lane.sampling, lane.rng) {
                Ok(tokens) => emitted[i] = Some(tokens),
                Err(e) => failed[i] = Some(e),
            }
        }
        timings.verify = t0.elapsed().as_secs_f64();
        crate::trace::phase(tr0, crate::trace::Phase::Verify, n as u64);

        // Resolve per-lane outcomes + the step's occupancy accounting.
        let mut outcomes = Vec::with_capacity(n);
        for (i, lane) in lanes.iter().enumerate() {
            let outcome = if let Some(e) = failed[i].take() {
                LaneOutcome::Failed(e)
            } else if let Some(e) = suspect[i].take() {
                LaneOutcome::Suspect(e)
            } else if let Some(tokens) = emitted[i].take() {
                timings.lanes += 1;
                if is_fused(lane) {
                    timings.batched_lanes += 1;
                }
                LaneOutcome::Emitted(tokens)
            } else {
                LaneOutcome::Idle
            };
            outcomes.push(outcome);
        }
        timings.dispatches = decoder.dispatch_count() - dispatches0;
        (outcomes, timings)
    }

    /// A shared fused dispatch failed: QUARANTINE every adopted lane
    /// with a block still in flight instead of killing it (the old
    /// `fail_fused` mass-terminal). The lane's host sequence is intact;
    /// its RNG is rewound to the block start so the salvaged re-run
    /// draws the same samples a fault-free run would have. Lanes that
    /// already resolved this step (emitted/failed) and per-lane fallback
    /// lanes are untouched.
    fn quarantine_fused(
        lanes: &mut [Lane<'_>],
        blocks: &mut [Option<BlockState>],
        emitted: &[Option<Vec<u32>>],
        suspect: &mut [Option<Error>],
        rng0: &[Pcg64],
        e: &Error,
    ) {
        for (i, lane) in lanes.iter_mut().enumerate() {
            if !lane.session.lane_mode() || suspect[i].is_some() || emitted[i].is_some() {
                continue;
            }
            if blocks[i].take().is_none() {
                continue;
            }
            *lane.rng = rng0[i].clone();
            suspect[i] = Some(Error::msg(format!("fused batched dispatch failed: {e}")));
        }
    }
}

#[cfg(test)]
mod tests {
    // BatchStep needs live sessions (compiled artifacts); its end-to-end
    // behaviour — batched output == direct engine output, per-phase
    // lockstep, shrunken-gamma lanes sitting out late rounds, fused-path
    // dispatch counts — is covered by rust/tests/coordinator_integration.rs
    // and rust/tests/batched_integration.rs. The phase-capacity arithmetic
    // is unit-tested in crate::spec (shrunken_gamma), the arena/staging
    // invariants in crate::runtime.
    use super::PhaseTimings;

    #[test]
    fn timings_default_zero() {
        let t = PhaseTimings::default();
        assert_eq!(t.draft_sync + t.propose + t.verify, 0.0);
        assert_eq!(t.dispatches, 0);
        assert_eq!(t.lanes + t.batched_lanes, 0);
    }
}

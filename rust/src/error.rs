//! Crate-wide error type (thiserror is unavailable offline; the Display
//! and From impls are written by hand, same substrate policy as
//! [`crate::json`] / [`crate::cli`]).

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Xla(xla::Error),
    Io(std::io::Error),
    Json { offset: usize, msg: String },
    Manifest(String),
    Weights(String),
    Tokenizer(String),
    KvCache(String),
    Scheduler(String),
    Cli(String),
    /// A thread-pool worker job panicked; the panic payload (stringified)
    /// is delivered to the waiter instead of stranding it.
    Worker(String),
    /// Deterministic fault injected by an armed [`crate::faults`] plan.
    /// `transient` drives the retry/breaker taxonomy split.
    Fault { transient: bool, msg: String },
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Manifest(m) => write!(f, "manifest: {m}"),
            Error::Weights(m) => write!(f, "weights file: {m}"),
            Error::Tokenizer(m) => write!(f, "tokenizer: {m}"),
            Error::KvCache(m) => write!(f, "kv cache: {m}"),
            Error::Scheduler(m) => write!(f, "scheduler: {m}"),
            Error::Cli(m) => write!(f, "cli: {m}"),
            Error::Worker(m) => write!(f, "worker panic: {m}"),
            Error::Fault { transient, msg } => {
                let kind = if *transient { "transient" } else { "permanent" };
                write!(f, "injected fault ({kind}): {msg}")
            }
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }

    /// Transient errors are worth retrying: backend/IO hiccups and faults
    /// injected in transient mode. Everything else (bad manifests, logic
    /// errors, permanent faults) fails fast — retrying cannot help.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            Error::Fault { transient: true, .. } | Error::Xla(_) | Error::Io(_)
        )
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_format() {
        assert_eq!(Error::msg("plain").to_string(), "plain");
        assert_eq!(Error::Cli("bad flag".into()).to_string(), "cli: bad flag");
        assert_eq!(
            Error::Json { offset: 7, msg: "oops".into() }.to_string(),
            "json parse error at byte 7: oops"
        );
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "gone").into();
        assert!(e.to_string().starts_with("io: "));
    }

    #[test]
    fn transient_taxonomy() {
        assert!(Error::Fault { transient: true, msg: "x".into() }.is_transient());
        assert!(!Error::Fault { transient: false, msg: "x".into() }.is_transient());
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "gone").into();
        assert!(io.is_transient());
        assert!(!Error::Scheduler("down".into()).is_transient());
        assert!(!Error::Worker("boom".into()).is_transient());
        assert!(
            Error::Fault { transient: false, msg: "disk".into() }
                .to_string()
                .contains("permanent")
        );
    }
}

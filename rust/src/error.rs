//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("manifest: {0}")]
    Manifest(String),

    #[error("weights file: {0}")]
    Weights(String),

    #[error("tokenizer: {0}")]
    Tokenizer(String),

    #[error("kv cache: {0}")]
    KvCache(String),

    #[error("scheduler: {0}")]
    Scheduler(String),

    #[error("cli: {0}")]
    Cli(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

//! Flight-recorder tracing: bounded ring of spans from HTTP accept down to
//! individual PJRT dispatches.
//!
//! The recorder is a process-global (like the `log` crate's facade) so the
//! hook sites in [`crate::runtime`], [`crate::batch`], [`crate::spec`] and
//! [`crate::coordinator`] don't have to thread a handle through every
//! signature. Disabled tracing costs one relaxed atomic load per site:
//! [`begin`] returns the sentinel `0` and every recording call bails on it
//! before taking a timestamp or the ring lock (the dispatch microbench
//! hard-asserts this stays under 1% of a token's budget).
//!
//! Three consumers share the ring:
//!
//! 1. `--trace-out <path>` writes Chrome trace-event JSON ([`write_chrome_trace`];
//!    loadable in Perfetto / `chrome://tracing`). Scheduler work (iterations,
//!    waves, phases, dispatches) lands on one track as nested `ph:"X"`
//!    duration events; request lifecycle marks (queued, admitted, per-block
//!    acceptance, terminal) are `ph:"i"` instants on a second track.
//! 2. `/debug/trace` and `/debug/requests/<id>` snapshot the ring for a live
//!    server ([`chrome_trace_json`], [`request_timeline_json`]).
//! 3. `--log-requests` emits one structured JSON access-log line per request
//!    terminal on stderr ([`access_log`]).
//!
//! Request IDs are the coordinator's `u64`s; the client-facing string IDs
//! (honored `X-Request-Id` or generated `req-<n>`) live in a bounded side
//! map ([`register_rid`]) so the wire strings never enter the fixed-size
//! [`Event`].

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::json::ObjWriter;

/// Default ring capacity: ~3 MB of events, minutes of serving at typical
/// dispatch rates.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Client-facing request-id strings are clipped to this many bytes.
pub const MAX_RID_LEN: usize = 128;

/// At most this many request-id strings are retained (oldest evicted).
const MAX_RIDS: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static RECORDER: Mutex<Option<Recorder>> = Mutex::new(None);

// ---------------------------------------------------------------------------
// Event model
// ---------------------------------------------------------------------------

/// Engine phase within one batch step (see `batch::BatchStep::run`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    DraftSync,
    Propose,
    Verify,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::DraftSync => "draft_sync",
            Phase::Propose => "propose",
            Phase::Verify => "verify",
        }
    }
}

/// What a PJRT dispatch was for (entry point or staging helper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchKind {
    Prefill,
    Decode,
    Verify,
    Pack,
    Extract,
}

impl DispatchKind {
    pub fn name(self) -> &'static str {
        match self {
            DispatchKind::Prefill => "prefill",
            DispatchKind::Decode => "decode",
            DispatchKind::Verify => "verify",
            DispatchKind::Pack => "pack",
            DispatchKind::Extract => "extract",
        }
    }

    /// Map a runtime entry name ("prefill"/"verify"/"decode") to a kind.
    pub fn from_entry(name: &str) -> DispatchKind {
        match name {
            "prefill" => DispatchKind::Prefill,
            "verify" => DispatchKind::Verify,
            _ => DispatchKind::Decode,
        }
    }
}

/// Why a request reached its terminal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reason {
    Ok,
    Deadline,
    Disconnect,
    Error,
}

impl Reason {
    pub fn name(self) -> &'static str {
        match self {
            Reason::Ok => "ok",
            Reason::Deadline => "deadline",
            Reason::Disconnect => "disconnect",
            Reason::Error => "error",
        }
    }

    /// Classify a terminal `Response::error` string.
    pub fn from_error(err: Option<&str>) -> Reason {
        match err {
            None => Reason::Ok,
            Some(crate::coordinator::ERR_DEADLINE) => Reason::Deadline,
            Some(crate::coordinator::ERR_DISCONNECT) => Reason::Disconnect,
            Some(_) => Reason::Error,
        }
    }

    /// The HTTP status class this terminal maps to (499 = client hung up,
    /// following the nginx convention; used by the access log where the
    /// real wire status is out of reach).
    pub fn status(self) -> u16 {
        match self {
            Reason::Ok => 200,
            Reason::Deadline => 408,
            Reason::Disconnect => 499,
            Reason::Error => 500,
        }
    }
}

/// Discriminates what an [`Event`]'s payload words mean.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Scheduler iteration span; a = lane-steps (occupancy), b = dispatches.
    Iteration,
    /// Prefill admission wave span; a = lanes, b = prompt tokens.
    Wave,
    /// Engine phase span; a = lanes stepped.
    Phase(Phase),
    /// PJRT dispatch span; a = executable launches, b = bytes staged.
    Dispatch(DispatchKind),
    /// Request entered the admission queue.
    ReqQueued,
    /// Request admitted to a decode slot; a = queue wait in µs.
    ReqAdmitted,
    /// One speculative block finished; a = accepted drafts, b = tokens emitted.
    ReqBlock,
    /// Request terminal; a = total tokens delivered.
    ReqTerminal(Reason),
    /// Acceptance-drift detector fired; a = CUSUM score (milli-units),
    /// b = window accept-rate (milli-units).
    Drift,
    /// Fault injected by the armed plan; a = site index
    /// (`faults::Site`), b = 1 transient / 0 permanent.
    Fault,
    /// Transient dispatch failure absorbed by backoff retry; a = site
    /// index, b = attempt number.
    Retry,
    /// Lane re-prefilled after a suspect fused dispatch; a = tokens
    /// replayed (prompt + already-emitted).
    Salvage,
    /// Circuit-breaker transition; a = model (0 draft, 1 target),
    /// b = new state (0 closed, 1 open, 2 half-open).
    Breaker,
    /// Draft-bundle swap attempt resolved; a = serving generation after
    /// the attempt, b = outcome (0 adopted, 1 rejected).
    Swap,
    /// Guarded adoption rolled back to last-known-good; a = serving
    /// generation after rollback, b = trigger (0 drift, 1 accept floor,
    /// 2 breaker open).
    Rollback,
    /// Supervisor restarted the scheduler loop after a panic; a = restart
    /// count, b = residents re-admitted into the fresh loop.
    SchedRestart,
}

/// One fixed-size ring entry. `req` is 0 for scheduler-scoped events.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    pub ts_us: u64,
    pub dur_us: u64,
    pub kind: Kind,
    pub req: u64,
    pub a: u64,
    pub b: u64,
}

// ---------------------------------------------------------------------------
// Recorder (behind the global mutex)
// ---------------------------------------------------------------------------

struct Recorder {
    buf: Vec<Event>,
    head: u64, // total events ever pushed; buf index = head % cap
    cap: usize,
    rids: VecDeque<(u64, String)>,
}

impl Recorder {
    fn new(cap: usize) -> Recorder {
        let cap = cap.max(16);
        Recorder { buf: Vec::with_capacity(cap.min(4096)), head: 0, cap, rids: VecDeque::new() }
    }

    fn push(&mut self, ev: Event) {
        let i = (self.head % self.cap as u64) as usize;
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[i] = ev;
        }
        self.head += 1;
    }

    /// Retained events, oldest first (push order).
    fn snapshot(&self) -> Vec<Event> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let i = (self.head % self.cap as u64) as usize;
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&self.buf[i..]);
            out.extend_from_slice(&self.buf[..i]);
            out
        }
    }
}

fn lock_recorder() -> std::sync::MutexGuard<'static, Option<Recorder>> {
    RECORDER.lock().unwrap_or_else(|p| p.into_inner())
}

fn record(ev: Event) {
    if let Some(r) = lock_recorder().as_mut() {
        r.push(ev);
    }
}

fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

// ---------------------------------------------------------------------------
// Control surface
// ---------------------------------------------------------------------------

/// The per-site fast path: one relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on with a fresh ring of `cap` events (min 16).
pub fn enable(cap: usize) {
    let _ = EPOCH.get_or_init(Instant::now);
    *lock_recorder() = Some(Recorder::new(cap));
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording. The ring is retained for late exports/snapshots.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Open a span: the starting timestamp, or `0` when tracing is disabled
/// (every span-closing call treats `0` as "don't record").
#[inline]
pub fn begin() -> u64 {
    if !enabled() {
        return 0;
    }
    now_us().max(1)
}

fn span(t0: u64, kind: Kind, req: u64, a: u64, b: u64) {
    if t0 == 0 || !enabled() {
        return;
    }
    let end = now_us();
    record(Event { ts_us: t0, dur_us: end.saturating_sub(t0), kind, req, a, b });
}

fn instant(kind: Kind, req: u64, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    record(Event { ts_us: now_us(), dur_us: 0, kind, req, a, b });
}

// ---------------------------------------------------------------------------
// Recording hooks (called from the serving stack)
// ---------------------------------------------------------------------------

/// Close a scheduler-iteration span (`lane_steps` = occupancy that step).
pub fn iteration(t0: u64, lane_steps: u64, dispatches: u64) {
    span(t0, Kind::Iteration, 0, lane_steps, dispatches);
}

/// Close a prefill admission-wave span.
pub fn wave(t0: u64, lanes: u64, tokens: u64) {
    span(t0, Kind::Wave, 0, lanes, tokens);
}

/// Close an engine-phase span.
pub fn phase(t0: u64, which: Phase, lanes: u64) {
    span(t0, Kind::Phase(which), 0, lanes, 0);
}

/// Close a PJRT dispatch span (`calls` executable launches, `bytes` staged
/// host->device for compute dispatches / read back for extracts).
pub fn dispatch(t0: u64, kind: DispatchKind, calls: u64, bytes: u64) {
    span(t0, Kind::Dispatch(kind), 0, calls, bytes);
}

/// Request entered the admission queue.
pub fn req_queued(id: u64) {
    instant(Kind::ReqQueued, id, 0, 0);
}

/// Request left the queue for a decode slot.
pub fn req_admitted(id: u64, queue_wait_us: u64) {
    instant(Kind::ReqAdmitted, id, queue_wait_us, 0);
}

/// One speculative block finished for this request.
pub fn req_block(id: u64, accepted: u64, emitted: u64) {
    instant(Kind::ReqBlock, id, accepted, emitted);
}

/// Request reached its terminal.
pub fn req_terminal(id: u64, reason: Reason, tokens_out: u64) {
    instant(Kind::ReqTerminal(reason), id, tokens_out, 0);
}

/// The telemetry layer's acceptance-drift detector fired. Values are in
/// milli-units (×1000) so they ride the ring's integer payload slots.
pub fn drift(score_milli: u64, accept_rate_milli: u64) {
    instant(Kind::Drift, 0, score_milli, accept_rate_milli);
}

/// A fault was injected at `site` (see `faults::Site` for the index).
pub fn fault(site: u64, transient: bool) {
    instant(Kind::Fault, 0, site, u64::from(transient));
}

/// A transient dispatch failure is being retried (attempt N of budget).
pub fn retry(site: u64, attempt: u64) {
    instant(Kind::Retry, 0, site, attempt);
}

/// A quarantined lane was re-prefilled and resumed mid-stream.
pub fn salvage(id: u64, tokens_replayed: u64) {
    instant(Kind::Salvage, id, tokens_replayed, 0);
}

/// A circuit breaker changed state (model 0 draft / 1 target).
pub fn breaker(model: u64, state: u64) {
    instant(Kind::Breaker, 0, model, state);
}

/// A draft-bundle swap attempt resolved (outcome 0 adopted / 1 rejected).
pub fn swap(generation: u64, outcome: u64) {
    instant(Kind::Swap, 0, generation, outcome);
}

/// A guarded adoption rolled back to the last-known-good draft
/// (reason 0 drift / 1 accept floor / 2 breaker open).
pub fn rollback(generation: u64, reason: u64) {
    instant(Kind::Rollback, 0, generation, reason);
}

/// The supervisor restarted the scheduler loop after a panic.
pub fn sched_restart(count: u64, readmitted: u64) {
    instant(Kind::SchedRestart, 0, count, readmitted);
}

/// Remember the client-facing string ID for a request (bounded; oldest
/// evicted; clipped to [`MAX_RID_LEN`] bytes). No-op while disabled.
pub fn register_rid(id: u64, rid: &str) {
    if !enabled() {
        return;
    }
    let rid = if rid.len() > MAX_RID_LEN {
        let mut cut = MAX_RID_LEN;
        while !rid.is_char_boundary(cut) {
            cut -= 1;
        }
        &rid[..cut]
    } else {
        rid
    };
    if let Some(r) = lock_recorder().as_mut() {
        if let Some(slot) = r.rids.iter_mut().find(|(i, _)| *i == id) {
            slot.1 = rid.to_string();
            return;
        }
        if r.rids.len() >= MAX_RIDS {
            r.rids.pop_front();
        }
        r.rids.push_back((id, rid.to_string()));
    }
}

/// Look up a request's string ID (works even after [`disable`]).
pub fn rid_of(id: u64) -> Option<String> {
    lock_recorder()
        .as_ref()
        .and_then(|r| r.rids.iter().find(|(i, _)| *i == id).map(|(_, s)| s.clone()))
}

/// Retained ring contents, oldest first. Empty when never enabled.
pub fn snapshot() -> Vec<Event> {
    lock_recorder().as_ref().map(|r| r.snapshot()).unwrap_or_default()
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

const PID: u64 = 1;
const TID_SCHED: u64 = 1; // scheduler thread: iterations/waves/phases/dispatches
const TID_REQS: u64 = 2; // request lifecycle instants

fn event_json(ev: &Event) -> String {
    let mut w = ObjWriter::new().num("pid", PID as f64);
    match ev.kind {
        Kind::Iteration => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "X")
                .str("name", "iteration")
                .str("cat", "sched")
                .num("ts", ev.ts_us as f64)
                .num("dur", ev.dur_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("lane_steps", ev.a as f64)
                        .num("dispatches", ev.b as f64)
                        .finish(),
                );
        }
        Kind::Wave => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "X")
                .str("name", "wave")
                .str("cat", "sched")
                .num("ts", ev.ts_us as f64)
                .num("dur", ev.dur_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("lanes", ev.a as f64)
                        .num("prompt_tokens", ev.b as f64)
                        .finish(),
                );
        }
        Kind::Phase(p) => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "X")
                .str("name", p.name())
                .str("cat", "phase")
                .num("ts", ev.ts_us as f64)
                .num("dur", ev.dur_us as f64)
                .raw("args", &ObjWriter::new().num("lanes", ev.a as f64).finish());
        }
        Kind::Dispatch(k) => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "X")
                .str("name", k.name())
                .str("cat", "dispatch")
                .num("ts", ev.ts_us as f64)
                .num("dur", ev.dur_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("calls", ev.a as f64)
                        .num("bytes", ev.b as f64)
                        .finish(),
                );
        }
        Kind::ReqQueued => {
            w = w
                .num("tid", TID_REQS as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "req_queued")
                .str("cat", "req")
                .num("ts", ev.ts_us as f64)
                .raw("args", &ObjWriter::new().num("req", ev.req as f64).finish());
        }
        Kind::ReqAdmitted => {
            w = w
                .num("tid", TID_REQS as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "req_admitted")
                .str("cat", "req")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("req", ev.req as f64)
                        .num("queue_wait_us", ev.a as f64)
                        .finish(),
                );
        }
        Kind::ReqBlock => {
            w = w
                .num("tid", TID_REQS as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "req_block")
                .str("cat", "req")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("req", ev.req as f64)
                        .num("accepted", ev.a as f64)
                        .num("emitted", ev.b as f64)
                        .finish(),
                );
        }
        Kind::ReqTerminal(reason) => {
            w = w
                .num("tid", TID_REQS as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "req_terminal")
                .str("cat", "req")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("req", ev.req as f64)
                        .str("reason", reason.name())
                        .num("tokens_out", ev.a as f64)
                        .finish(),
                );
        }
        Kind::Drift => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "drift")
                .str("cat", "health")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("score_milli", ev.a as f64)
                        .num("accept_rate_milli", ev.b as f64)
                        .finish(),
                );
        }
        Kind::Fault => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "fault")
                .str("cat", "fault")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .str("site", site_name(ev.a))
                        .bool("transient", ev.b == 1)
                        .finish(),
                );
        }
        Kind::Retry => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "retry")
                .str("cat", "fault")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .str("site", site_name(ev.a))
                        .num("attempt", ev.b as f64)
                        .finish(),
                );
        }
        Kind::Salvage => {
            w = w
                .num("tid", TID_REQS as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "salvage")
                .str("cat", "fault")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("req", ev.req as f64)
                        .num("tokens_replayed", ev.a as f64)
                        .finish(),
                );
        }
        Kind::Breaker => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "breaker")
                .str("cat", "fault")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .str("model", if ev.a == 0 { "draft" } else { "target" })
                        .str(
                            "state",
                            match ev.b {
                                0 => "closed",
                                1 => "open",
                                _ => "half_open",
                            },
                        )
                        .finish(),
                );
        }
        Kind::Swap => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "draft_swap")
                .str("cat", "health")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("generation", ev.a as f64)
                        .str("outcome", if ev.b == 0 { "adopted" } else { "rejected" })
                        .finish(),
                );
        }
        Kind::Rollback => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "draft_rollback")
                .str("cat", "health")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("generation", ev.a as f64)
                        .str(
                            "trigger",
                            match ev.b {
                                0 => "drift",
                                1 => "accept_floor",
                                _ => "breaker_open",
                            },
                        )
                        .finish(),
                );
        }
        Kind::SchedRestart => {
            w = w
                .num("tid", TID_SCHED as f64)
                .str("ph", "i")
                .str("s", "t")
                .str("name", "sched_restart")
                .str("cat", "health")
                .num("ts", ev.ts_us as f64)
                .raw(
                    "args",
                    &ObjWriter::new()
                        .num("count", ev.a as f64)
                        .num("readmitted", ev.b as f64)
                        .finish(),
                );
        }
    }
    w.finish()
}

/// `faults::Site` index -> grammar spelling for trace export (kept here so
/// the exporter has no dependency on the faults module's types).
fn site_name(i: u64) -> &'static str {
    match i {
        0 => "dispatch:run_lanes",
        1 => "dispatch:run_into",
        2 => "dispatch:pack_lane",
        3 => "exec:send",
        4 => "io:read",
        5 => "io:write",
        6 => "swap:stage",
        7 => "swap:readmit",
        _ => "unknown",
    }
}

fn thread_meta(tid: u64, name: &str) -> String {
    ObjWriter::new()
        .num("pid", PID as f64)
        .num("tid", tid as f64)
        .str("ph", "M")
        .str("name", "thread_name")
        .raw("args", &ObjWriter::new().str("name", name).finish())
        .finish()
}

/// The whole retained ring as Chrome trace-event JSON (`{"traceEvents":[...]}`),
/// events sorted by timestamp so consumers see a monotonic stream.
pub fn chrome_trace_json() -> String {
    let mut events = snapshot();
    events.sort_by_key(|e| e.ts_us);
    let mut out = String::with_capacity(events.len() * 160 + 64);
    out.push_str("{\"traceEvents\":[");
    out.push_str(&thread_meta(TID_SCHED, "scheduler"));
    out.push(',');
    out.push_str(&thread_meta(TID_REQS, "requests"));
    for ev in &events {
        out.push(',');
        out.push_str(&event_json(ev));
    }
    out.push_str("]}");
    out
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(path: &str) -> crate::Result<()> {
    std::fs::write(path, chrome_trace_json())
        .map_err(|e| crate::Error::msg(format!("trace-out {path}: {e}")))
}

/// One request's lifecycle timeline as JSON, or `None` if the ring holds
/// nothing for it (-> 404 on the debug endpoint).
pub fn request_timeline_json(id: u64) -> Option<String> {
    let events: Vec<Event> =
        snapshot().into_iter().filter(|e| e.req == id && matches!(
            e.kind,
            Kind::ReqQueued | Kind::ReqAdmitted | Kind::ReqBlock | Kind::ReqTerminal(_)
                | Kind::Salvage
        )).collect();
    let rid = rid_of(id);
    if events.is_empty() && rid.is_none() {
        return None;
    }
    let mut arr = String::from("[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            arr.push(',');
        }
        arr.push_str(&event_json(ev));
    }
    arr.push(']');
    let mut w = ObjWriter::new().num("id", id as f64);
    if let Some(rid) = rid {
        w = w.str("request_id", &rid);
    }
    Some(w.raw("events", &arr).finish())
}

/// Resolve `/debug/requests/<id>` path segments: a numeric coordinator ID
/// or a registered string ID.
pub fn resolve_request_id(segment: &str) -> Option<u64> {
    if let Ok(n) = segment.parse::<u64>() {
        return Some(n);
    }
    lock_recorder()
        .as_ref()
        .and_then(|r| r.rids.iter().find(|(_, s)| s == segment).map(|(i, _)| *i))
}

// ---------------------------------------------------------------------------
// Structured access log
// ---------------------------------------------------------------------------

/// Everything one access-log line carries.
pub struct AccessRecord<'a> {
    pub id: u64,
    pub status: u16,
    pub tokens_in: usize,
    pub tokens_out: usize,
    pub ttft_s: f64,
    pub latency_s: f64,
    pub accept_rate: f64,
    pub reason: &'a str,
}

/// Render one access-log line (parseable JSON object).
pub fn access_line(rec: &AccessRecord) -> String {
    let rid = rid_of(rec.id).unwrap_or_else(|| {
        let mut s = String::from("req-");
        let _ = write!(s, "{}", rec.id);
        s
    });
    ObjWriter::new()
        .str("request_id", &rid)
        .num("status", rec.status as f64)
        .num("tokens_in", rec.tokens_in as f64)
        .num("tokens_out", rec.tokens_out as f64)
        .num("ttft_s", rec.ttft_s)
        .num("latency_s", rec.latency_s)
        .num("accept_rate", rec.accept_rate)
        .str("reason", rec.reason)
        .finish()
}

/// Emit one access-log line on stderr.
pub fn access_log(rec: &AccessRecord) {
    eprintln!("{}", access_line(rec));
}

// ---------------------------------------------------------------------------
// Tests (serialized: the recorder is process-global and `cargo test` runs
// lib unit tests in one process)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;

    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_sites_are_noops() {
        let _g = guard();
        enable(16);
        disable();
        assert_eq!(begin(), 0, "disabled begin() must return the sentinel");
        // None of these may reach the ring while disabled.
        iteration(123, 1, 1);
        phase(123, Phase::Verify, 1);
        dispatch(123, DispatchKind::Decode, 1, 64);
        req_queued(7);
        req_terminal(7, Reason::Ok, 3);
        register_rid(7, "client-id");
        assert!(snapshot().is_empty(), "disabled hooks leaked into the ring");
        assert_eq!(rid_of(7), None);
        // Span-closing calls must also ignore the 0 sentinel when enabled.
        enable(16);
        dispatch(0, DispatchKind::Decode, 1, 64);
        assert!(snapshot().is_empty(), "t0==0 must be a no-op");
        disable();
    }

    #[test]
    fn ring_wraps_and_keeps_newest() {
        let _g = guard();
        enable(16); // cap floor
        for i in 0..40u64 {
            req_queued(i);
        }
        let evs = snapshot();
        assert_eq!(evs.len(), 16, "ring must stay bounded");
        let ids: Vec<u64> = evs.iter().map(|e| e.req).collect();
        let want: Vec<u64> = (24..40).collect();
        assert_eq!(ids, want, "oldest events must be evicted in order");
        disable();
    }

    #[test]
    fn chrome_export_parses_and_orders_timestamps() {
        let _g = guard();
        enable(64);
        let t_it = begin();
        let t_ph = begin();
        dispatch(begin(), DispatchKind::Verify, 1, 4096);
        phase(t_ph, Phase::Verify, 2);
        iteration(t_it, 2, 7);
        req_queued(3);
        req_admitted(3, 120);
        req_block(3, 2, 3);
        req_terminal(3, Reason::Ok, 3);
        let text = chrome_trace_json();
        disable();
        let v = Value::parse(&text).expect("chrome trace must be valid JSON");
        let evs = v.get("traceEvents").as_arr().expect("traceEvents array");
        // 2 thread-name metadata records + 8 events above.
        assert_eq!(evs.len(), 10, "got: {text}");
        let mut last_ts = 0.0f64;
        for e in evs {
            assert_eq!(e.get("pid").as_usize(), Some(1));
            let ph = e.get("ph").as_str().unwrap();
            if ph == "M" {
                continue;
            }
            let ts = e.get("ts").as_f64().unwrap();
            assert!(ts >= last_ts, "timestamps must be sorted: {ts} < {last_ts}");
            last_ts = ts;
            if ph == "X" {
                assert!(e.get("dur").as_f64().unwrap() >= 0.0);
            } else {
                assert_eq!(ph, "i");
                assert_eq!(e.get("s").as_str(), Some("t"));
            }
        }
        // The verify dispatch must sit inside the verify phase span, which
        // sits inside the iteration span (containment = Perfetto nesting).
        let find = |name: &str, cat: &str| {
            evs.iter()
                .find(|e| {
                    e.get("name").as_str() == Some(name) && e.get("cat").as_str() == Some(cat)
                })
                .unwrap_or_else(|| panic!("missing {cat}/{name}: {text}"))
        };
        let it = find("iteration", "sched");
        let phv = find("verify", "phase");
        let d = find("verify", "dispatch");
        let span = |e: &Value| {
            let ts = e.get("ts").as_f64().unwrap();
            (ts, ts + e.get("dur").as_f64().unwrap())
        };
        let (i0, i1) = span(it);
        let (p0, p1) = span(phv);
        let (d0, d1) = span(d);
        assert!(i0 <= p0 && p1 <= i1, "phase not nested in iteration");
        assert!(p0 <= d0 && d1 <= p1, "dispatch not nested in phase");
    }

    #[test]
    fn fault_instants_export_with_fault_category() {
        let _g = guard();
        enable(64);
        fault(0, true);
        retry(0, 1);
        salvage(9, 37);
        breaker(0, 1);
        breaker(0, 2);
        breaker(0, 0);
        let text = chrome_trace_json();
        disable();
        let v = Value::parse(&text).expect("chrome trace must be valid JSON");
        let evs = v.get("traceEvents").as_arr().expect("traceEvents array");
        let find = |name: &str| {
            evs.iter()
                .find(|e| {
                    e.get("name").as_str() == Some(name)
                        && e.get("cat").as_str() == Some("fault")
                })
                .unwrap_or_else(|| panic!("missing fault/{name}: {text}"))
        };
        let f = find("fault");
        assert_eq!(f.get("args").get("site").as_str(), Some("dispatch:run_lanes"));
        assert_eq!(f.get("args").get("transient").as_bool(), Some(true));
        let r = find("retry");
        assert_eq!(r.get("args").get("attempt").as_usize(), Some(1));
        let s = find("salvage");
        assert_eq!(s.get("args").get("req").as_usize(), Some(9));
        assert_eq!(s.get("args").get("tokens_replayed").as_usize(), Some(37));
        let states: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("name").as_str() == Some("breaker"))
            .filter_map(|e| e.get("args").get("state").as_str())
            .collect();
        assert_eq!(states, ["open", "half_open", "closed"]);
        // Salvage instants are request-scoped: they join the timeline view.
        enable(64);
        salvage(9, 37);
        let tl = request_timeline_json(9).expect("salvage alone yields a timeline");
        disable();
        assert!(tl.contains("salvage"), "timeline missing salvage: {tl}");
    }

    #[test]
    fn rid_map_is_bounded_and_clipped() {
        let _g = guard();
        enable(16);
        let long = "x".repeat(MAX_RID_LEN + 40);
        register_rid(1, &long);
        assert_eq!(rid_of(1).unwrap().len(), MAX_RID_LEN);
        register_rid(1, "client-abc"); // re-register replaces
        assert_eq!(rid_of(1).as_deref(), Some("client-abc"));
        for i in 0..(MAX_RIDS as u64 + 50) {
            register_rid(1000 + i, "r");
        }
        let held = lock_recorder().as_ref().unwrap().rids.len();
        assert!(held <= MAX_RIDS, "rid map grew unbounded: {held}");
        assert_eq!(rid_of(1), None, "oldest rid must be evicted");
        assert_eq!(resolve_request_id("42"), Some(42));
        register_rid(77, "claimable");
        assert_eq!(resolve_request_id("claimable"), Some(77));
        assert_eq!(resolve_request_id("unknown-rid"), None);
        disable();
    }

    #[test]
    fn reason_classification_matches_coordinator_errors() {
        assert_eq!(Reason::from_error(None), Reason::Ok);
        assert_eq!(
            Reason::from_error(Some(crate::coordinator::ERR_DEADLINE)),
            Reason::Deadline
        );
        assert_eq!(
            Reason::from_error(Some(crate::coordinator::ERR_DISCONNECT)),
            Reason::Disconnect
        );
        assert_eq!(Reason::from_error(Some("pool exhausted")), Reason::Error);
        assert_eq!(Reason::Ok.status(), 200);
        assert_eq!(Reason::Deadline.status(), 408);
    }

    #[test]
    fn access_line_is_parseable_json() {
        let _g = guard();
        enable(16);
        register_rid(9, "cli-9");
        let line = access_line(&AccessRecord {
            id: 9,
            status: 200,
            tokens_in: 12,
            tokens_out: 34,
            ttft_s: 0.05,
            latency_s: 0.5,
            accept_rate: 0.75,
            reason: "ok",
        });
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("request_id").as_str(), Some("cli-9"));
        assert_eq!(v.get("status").as_usize(), Some(200));
        assert_eq!(v.get("tokens_out").as_usize(), Some(34));
        assert_eq!(v.get("reason").as_str(), Some("ok"));
        disable();
        // Without a registered rid the line falls back to req-<id>.
        let line = access_line(&AccessRecord {
            id: 123456,
            status: 408,
            tokens_in: 1,
            tokens_out: 0,
            ttft_s: 0.0,
            latency_s: 1.0,
            accept_rate: 0.0,
            reason: "deadline",
        });
        assert_eq!(Value::parse(&line).unwrap().get("request_id").as_str(), Some("req-123456"));
    }

    #[test]
    fn request_timeline_filters_one_request() {
        let _g = guard();
        enable(64);
        req_queued(5);
        req_queued(6);
        req_admitted(5, 10);
        iteration(begin(), 1, 2); // scheduler event: req==0, excluded
        req_block(5, 3, 4);
        req_terminal(5, Reason::Ok, 4);
        let v = Value::parse(&request_timeline_json(5).unwrap()).unwrap();
        assert_eq!(v.get("id").as_usize(), Some(5));
        let evs = v.get("events").as_arr().unwrap();
        assert_eq!(evs.len(), 4);
        let names: Vec<&str> = evs.iter().map(|e| e.get("name").as_str().unwrap()).collect();
        assert_eq!(names, ["req_queued", "req_admitted", "req_block", "req_terminal"]);
        assert!(request_timeline_json(999).is_none(), "unknown request -> None -> 404");
        disable();
    }
}

//! HTTP/1.1 substrate (hyper/axum are unavailable offline).
//!
//! The wire layer for [`crate::server`]: a request parser with hard size
//! limits, a plain and a chunked response writer, and a client-side
//! response reader (used by the integration tests and
//! `examples/http_load.rs`). Scope is deliberately the subset the serving
//! subsystem needs:
//!
//! * requests: `GET`/`POST`, `Content-Length` bodies (chunked request
//!   bodies are refused with [`HttpError::Unsupported`] → 501),
//! * keep-alive: HTTP/1.1 default-on, HTTP/1.0 default-off, `Connection`
//!   header respected; pipelined requests fall out of the parser reading
//!   exactly one message per call,
//! * responses: fixed `Content-Length` bodies or `Transfer-Encoding:
//!   chunked` for streaming (each speculation block is flushed as one
//!   chunk),
//! * limits: request-line/header/body byte caps so a misbehaving client
//!   cannot balloon memory (431/413 at the server layer).
//!
//! Timeouts are the socket's (`set_read_timeout`); the parser surfaces
//! them as [`HttpError::TimedOut`] with a `started` flag so the connection
//! loop can distinguish an idle keep-alive (retry or close politely) from
//! a stalled mid-request client (close).

use std::collections::BTreeMap;
use std::io::{self, BufRead, Write};

// ---------------------------------------------------------------------------
// Limits
// ---------------------------------------------------------------------------

/// Byte/count caps applied while parsing one message.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Max bytes in the request line (method + target + version).
    pub max_request_line: usize,
    /// Max number of header fields.
    pub max_headers: usize,
    /// Max bytes in one header line.
    pub max_header_line: usize,
    /// Max body bytes (`Content-Length` above this is refused outright).
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_request_line: 8 * 1024,
            max_headers: 64,
            max_header_line: 8 * 1024,
            max_body: 1024 * 1024,
        }
    }
}

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Parse/transport failure while reading one HTTP message.
#[derive(Debug)]
pub enum HttpError {
    /// Clean EOF before the first byte of a message — the peer closed an
    /// idle (keep-alive) connection; not an error condition.
    Eof,
    /// The socket read timed out. `started` is true when part of a message
    /// had already been consumed (a stalled client, close the connection);
    /// false means an idle keep-alive wait (safe to retry).
    TimedOut { started: bool },
    /// A size limit tripped; the payload names which one (→ 431/413).
    TooLarge(&'static str),
    /// Syntactically invalid message (→ 400).
    Malformed(String),
    /// Valid HTTP we deliberately don't implement (→ 501).
    Unsupported(&'static str),
    Io(io::Error),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Eof => write!(f, "connection closed"),
            HttpError::TimedOut { started } => {
                write!(f, "read timed out (mid-request: {started})")
            }
            HttpError::TooLarge(what) => write!(f, "{what} too large"),
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::Unsupported(what) => write!(f, "unsupported: {what}"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

// ---------------------------------------------------------------------------
// Request parsing
// ---------------------------------------------------------------------------

/// One parsed request. Header names are lowercased at parse time.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    /// Path without the query string, e.g. `/v1/generate`.
    pub path: String,
    /// Decoded `k=v` query pairs (no percent-decoding; the serving API
    /// uses plain tokens like `stream=1`).
    pub query: BTreeMap<String, String>,
    /// True for HTTP/1.1, false for HTTP/1.0.
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this exchange.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(|v| v.to_ascii_lowercase()) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }

    /// Query flag: present and not `0`/`false`.
    pub fn query_flag(&self, name: &str) -> bool {
        match self.query.get(name) {
            Some(v) => v != "0" && !v.eq_ignore_ascii_case("false"),
            None => false,
        }
    }

    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

/// Read one line (terminated by `\n`, `\r\n` stripped) into `buf`.
/// `buf` must be empty on entry unless resuming after a timeout. The limit
/// is enforced *while* reading, so an endless line cannot balloon memory.
fn read_line(
    r: &mut impl BufRead,
    buf: &mut Vec<u8>,
    limit: usize,
    what: &'static str,
) -> Result<(), HttpError> {
    loop {
        let available = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                return Err(HttpError::TimedOut { started: !buf.is_empty() })
            }
            Err(e) => return Err(HttpError::Io(e)),
        };
        if available.is_empty() {
            return Err(if buf.is_empty() {
                HttpError::Eof
            } else {
                HttpError::Malformed("unexpected eof".into())
            });
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(i) => {
                buf.extend_from_slice(&available[..i]);
                r.consume(i + 1);
                if buf.len() > limit {
                    return Err(HttpError::TooLarge(what));
                }
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                return Ok(());
            }
            None => {
                let n = available.len();
                buf.extend_from_slice(available);
                r.consume(n);
                if buf.len() > limit {
                    return Err(HttpError::TooLarge(what));
                }
            }
        }
    }
}

/// Fill `out` completely, looping over short reads.
fn read_full(r: &mut impl BufRead, out: &mut [u8]) -> Result<(), HttpError> {
    let mut filled = 0;
    while filled < out.len() {
        match r.read(&mut out[filled..]) {
            Ok(0) => return Err(HttpError::Malformed("body truncated".into())),
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return Err(HttpError::TimedOut { started: true }),
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
    Ok(())
}

/// Parse exactly one request from `r`. Leaves the reader positioned at the
/// start of the next pipelined request (if any).
///
/// `continue_to`: writer for the interim `100 Continue` response — clients
/// like curl send `Expect: 100-continue` for non-trivial bodies and wait
/// for it before transmitting; without the interim response the body read
/// stalls into a timeout. Pass `None` when parsing from a byte buffer.
pub fn read_request(
    r: &mut impl BufRead,
    limits: &Limits,
    continue_to: Option<&mut dyn Write>,
) -> Result<HttpRequest, HttpError> {
    // --- request line ----------------------------------------------------
    let mut line = Vec::new();
    read_line(r, &mut line, limits.max_request_line, "request line")?;
    let line = String::from_utf8(line)
        .map_err(|_| HttpError::Malformed("request line not utf-8".into()))?;
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(HttpError::Malformed(format!("bad request line '{line}'"))),
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::Malformed(format!("bad version '{version}'"))),
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::Malformed(format!("bad method '{method}'")));
    }
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    if !path.starts_with('/') {
        return Err(HttpError::Malformed(format!("bad target '{target}'")));
    }
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(k.to_string(), v.to_string());
    }

    // --- headers ----------------------------------------------------------
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let mut hline = Vec::new();
        read_line(r, &mut hline, limits.max_header_line, "header line").map_err(|e| {
            // EOF between request line and blank line is malformed, and a
            // timeout here is always mid-request.
            match e {
                HttpError::Eof => HttpError::Malformed("eof in headers".into()),
                HttpError::TimedOut { .. } => HttpError::TimedOut { started: true },
                other => other,
            }
        })?;
        if hline.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::TooLarge("header count"));
        }
        let hline = String::from_utf8(hline)
            .map_err(|_| HttpError::Malformed("header not utf-8".into()))?;
        let (name, value) = hline
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("bad header '{hline}'")))?;
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::Malformed(format!("bad header name '{name}'")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req =
        HttpRequest { method: method.to_string(), path: path.to_string(), query, http11, headers, body: Vec::new() };

    // --- body -------------------------------------------------------------
    if let Some(te) = req.header("transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(HttpError::Unsupported("chunked request bodies"));
        }
    }
    if let Some(cl) = req.header("content-length") {
        let len: usize = cl
            .trim()
            .parse()
            .map_err(|_| HttpError::Malformed(format!("bad content-length '{cl}'")))?;
        if len > limits.max_body {
            return Err(HttpError::TooLarge("body"));
        }
        if len > 0
            && req.header("expect").is_some_and(|e| e.eq_ignore_ascii_case("100-continue"))
        {
            if let Some(w) = continue_to {
                w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").map_err(HttpError::Io)?;
                w.flush().map_err(HttpError::Io)?;
            }
        }
        let mut body = vec![0u8; len];
        read_full(r, &mut body)?;
        req.body = body;
    }
    Ok(req)
}

// ---------------------------------------------------------------------------
// Response writing
// ---------------------------------------------------------------------------

pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response.
pub fn write_response(
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra_headers: &[(&str, &str)],
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", code, status_reason(code))?;
    write!(w, "content-type: {content_type}\r\n")?;
    write!(w, "content-length: {}\r\n", body.len())?;
    write!(w, "connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Chunked-transfer streaming body. Construction writes the response head;
/// every [`ChunkedWriter::chunk`] is flushed immediately so clients observe
/// tokens as the scheduler produces them; [`ChunkedWriter::finish`] writes
/// the terminal zero-chunk (also attempted on drop, errors ignored).
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
    finished: bool,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    pub fn start(
        w: &'a mut W,
        code: u16,
        content_type: &str,
        keep_alive: bool,
        extra_headers: &[(&str, &str)],
    ) -> io::Result<Self> {
        write!(w, "HTTP/1.1 {} {}\r\n", code, status_reason(code))?;
        write!(w, "content-type: {content_type}\r\n")?;
        w.write_all(b"transfer-encoding: chunked\r\n")?;
        write!(w, "connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
        for (k, v) in extra_headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w, finished: false })
    }

    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the body
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    pub fn finish(mut self) -> io::Result<()> {
        self.finished = true;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

impl<W: Write> Drop for ChunkedWriter<'_, W> {
    fn drop(&mut self) {
        if !self.finished {
            let _ = self.w.write_all(b"0\r\n\r\n");
            let _ = self.w.flush();
        }
    }
}

// ---------------------------------------------------------------------------
// Client-side response reading (integration tests + load generator)
// ---------------------------------------------------------------------------

/// A parsed response. Header names are lowercased.
#[derive(Debug)]
pub struct HttpResponse {
    pub code: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }

    pub fn chunked(&self) -> bool {
        self.header("transfer-encoding").is_some_and(|v| v.eq_ignore_ascii_case("chunked"))
    }
}

/// Read status line + headers; the body is NOT consumed (callers pick
/// fixed-length vs chunked via [`read_body`] / [`ChunkedReader`]).
pub fn read_response_head(r: &mut impl BufRead) -> Result<HttpResponse, HttpError> {
    let mut line = Vec::new();
    read_line(r, &mut line, 8 * 1024, "status line")?;
    let line =
        String::from_utf8(line).map_err(|_| HttpError::Malformed("status not utf-8".into()))?;
    let mut parts = line.splitn(3, ' ');
    let (version, code) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("bad status line '{line}'")));
    }
    let code: u16 =
        code.parse().map_err(|_| HttpError::Malformed(format!("bad status code '{code}'")))?;
    let mut headers = Vec::new();
    loop {
        let mut hline = Vec::new();
        read_line(r, &mut hline, 8 * 1024, "header line")?;
        if hline.is_empty() {
            break;
        }
        let hline = String::from_utf8(hline)
            .map_err(|_| HttpError::Malformed("header not utf-8".into()))?;
        if let Some((k, v)) = hline.split_once(':') {
            headers.push((k.to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    Ok(HttpResponse { code, headers, body: Vec::new() })
}

/// Consume the body for `head` (fixed-length or chunked) and fill it in.
pub fn read_body(r: &mut impl BufRead, head: &mut HttpResponse) -> Result<(), HttpError> {
    if head.chunked() {
        let mut chunks = ChunkedReader::new(r);
        while let Some(c) = chunks.next_chunk()? {
            head.body.extend_from_slice(&c);
        }
    } else if let Some(cl) = head.header("content-length") {
        let len: usize = cl
            .parse()
            .map_err(|_| HttpError::Malformed(format!("bad content-length '{cl}'")))?;
        let mut body = vec![0u8; len];
        read_full(r, &mut body)?;
        head.body = body;
    }
    Ok(())
}

/// Read one full response (head + body).
pub fn read_response(r: &mut impl BufRead) -> Result<HttpResponse, HttpError> {
    let mut head = read_response_head(r)?;
    read_body(r, &mut head)?;
    Ok(head)
}

/// Incremental chunked-body reader: one `next_chunk` per wire chunk, so a
/// streaming client can timestamp each arrival (TTFT measurements).
pub struct ChunkedReader<'a, R: BufRead> {
    r: &'a mut R,
    done: bool,
}

impl<'a, R: BufRead> ChunkedReader<'a, R> {
    pub fn new(r: &'a mut R) -> Self {
        ChunkedReader { r, done: false }
    }

    /// `Ok(None)` after the terminal zero-chunk.
    pub fn next_chunk(&mut self) -> Result<Option<Vec<u8>>, HttpError> {
        if self.done {
            return Ok(None);
        }
        let mut line = Vec::new();
        read_line(self.r, &mut line, 1024, "chunk size")?;
        let size_str = String::from_utf8(line)
            .map_err(|_| HttpError::Malformed("chunk size not utf-8".into()))?;
        let size_str = size_str.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_str, 16)
            .map_err(|_| HttpError::Malformed(format!("bad chunk size '{size_str}'")))?;
        if size == 0 {
            // Trailer section: lines until the blank terminator.
            loop {
                let mut t = Vec::new();
                read_line(self.r, &mut t, 8 * 1024, "trailer")?;
                if t.is_empty() {
                    break;
                }
            }
            self.done = true;
            return Ok(None);
        }
        let mut data = vec![0u8; size];
        read_full(self.r, &mut data)?;
        let mut crlf = Vec::new();
        read_line(self.r, &mut crlf, 8, "chunk terminator")?;
        if !crlf.is_empty() {
            return Err(HttpError::Malformed("chunk not CRLF-terminated".into()));
        }
        Ok(Some(data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(bytes: &[u8]) -> Result<HttpRequest, HttpError> {
        read_request(&mut BufReader::new(bytes), &Limits::default(), None)
    }

    #[test]
    fn parses_get_with_query() {
        let r = parse(b"GET /v1/generate?stream=1&x=a HTTP/1.1\r\nHost: h\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/generate");
        assert_eq!(r.query.get("stream").map(|s| s.as_str()), Some("1"));
        assert!(r.query_flag("stream"));
        assert!(!r.query_flag("missing"));
        assert!(r.http11 && r.keep_alive());
        assert_eq!(r.header("host"), Some("h"));
        assert_eq!(r.header("HOST"), Some("h"));
    }

    #[test]
    fn parses_post_with_body() {
        let r = parse(b"POST /v1/generate HTTP/1.1\r\ncontent-length: 4\r\n\r\nabcd").unwrap();
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn keep_alive_rules() {
        assert!(!parse(b"GET / HTTP/1.0\r\n\r\n").unwrap().keep_alive());
        assert!(parse(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().keep_alive());
        assert!(!parse(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().keep_alive());
    }

    #[test]
    fn malformed_request_lines_rejected() {
        for bad in [
            &b"GARBAGE\r\n\r\n"[..],
            b"GET\r\n\r\n",
            b"GET / HTTP/2.0\r\n\r\n",
            b"GET / HTTP/1.1 extra\r\n\r\n",
            b"get / HTTP/1.1\r\n\r\n",
            b"GET nopath HTTP/1.1\r\n\r\n",
        ] {
            assert!(
                matches!(parse(bad), Err(HttpError::Malformed(_))),
                "accepted: {:?}",
                String::from_utf8_lossy(bad)
            );
        }
    }

    #[test]
    fn eof_before_request_is_clean_close() {
        assert!(matches!(parse(b""), Err(HttpError::Eof)));
    }

    #[test]
    fn truncated_body_is_malformed() {
        let r = parse(b"POST / HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc");
        assert!(matches!(r, Err(HttpError::Malformed(_))));
    }

    #[test]
    fn limits_enforced() {
        // Request line cap.
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(10_000));
        assert!(matches!(parse(long.as_bytes()), Err(HttpError::TooLarge("request line"))));
        // Body cap: declared length over the limit is refused before reading.
        let lim = Limits { max_body: 8, ..Limits::default() };
        let r = read_request(
            &mut BufReader::new(&b"POST / HTTP/1.1\r\ncontent-length: 9\r\n\r\n123456789"[..]),
            &lim,
            None,
        );
        assert!(matches!(r, Err(HttpError::TooLarge("body"))));
        // Header count cap.
        let mut many = String::from("GET / HTTP/1.1\r\n");
        for i in 0..100 {
            many.push_str(&format!("h{i}: v\r\n"));
        }
        many.push_str("\r\n");
        assert!(matches!(parse(many.as_bytes()), Err(HttpError::TooLarge("header count"))));
    }

    #[test]
    fn chunked_request_body_unsupported() {
        let r = parse(b"POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n");
        assert!(matches!(r, Err(HttpError::Unsupported(_))));
    }

    #[test]
    fn pipelined_requests_parse_sequentially() {
        let wire = b"POST /a HTTP/1.1\r\ncontent-length: 2\r\n\r\nhiGET /b HTTP/1.1\r\n\r\n";
        let mut rd = BufReader::new(&wire[..]);
        let a = read_request(&mut rd, &Limits::default(), None).unwrap();
        assert_eq!((a.path.as_str(), a.body.as_slice()), ("/a", &b"hi"[..]));
        let b = read_request(&mut rd, &Limits::default(), None).unwrap();
        assert_eq!(b.path, "/b");
        assert!(matches!(read_request(&mut rd, &Limits::default(), None), Err(HttpError::Eof)));
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        let wire = b"POST / HTTP/1.1\r\nexpect: 100-continue\r\ncontent-length: 2\r\n\r\nhi";
        let mut interim = Vec::new();
        let mut rd = BufReader::new(&wire[..]);
        let req =
            read_request(&mut rd, &Limits::default(), Some(&mut interim)).unwrap();
        assert_eq!(req.body, b"hi");
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        // Without the Expect header no interim bytes are written.
        let wire = b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\r\nhi";
        let mut interim = Vec::new();
        read_request(&mut BufReader::new(&wire[..]), &Limits::default(), Some(&mut interim))
            .unwrap();
        assert!(interim.is_empty());
    }

    #[test]
    fn write_then_read_response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, "application/json", b"{\"error\":\"busy\"}", true,
                       &[("retry-after", "1")])
            .unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.code, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.body_str(), "{\"error\":\"busy\"}");
    }

    #[test]
    fn chunked_writer_reader_roundtrip() {
        let mut wire = Vec::new();
        {
            let hdrs = [("x-request-id", "rid-42")];
            let mut cw =
                ChunkedWriter::start(&mut wire, 200, "text/event-stream", true, &hdrs).unwrap();
            cw.chunk(b"data: {\"tokens\":[1,2]}\n\n").unwrap();
            cw.chunk(b"").unwrap(); // ignored, must not terminate
            cw.chunk(b"data: done\n\n").unwrap();
            cw.finish().unwrap();
        }
        let mut rd = BufReader::new(&wire[..]);
        let head = read_response_head(&mut rd).unwrap();
        assert!(head.chunked());
        assert_eq!(head.header("x-request-id"), Some("rid-42"));
        let mut cr = ChunkedReader::new(&mut rd);
        assert_eq!(cr.next_chunk().unwrap().unwrap(), b"data: {\"tokens\":[1,2]}\n\n");
        assert_eq!(cr.next_chunk().unwrap().unwrap(), b"data: done\n\n");
        assert!(cr.next_chunk().unwrap().is_none());
        assert!(cr.next_chunk().unwrap().is_none(), "idempotent after terminator");
    }

    #[test]
    fn chunked_writer_terminates_on_drop() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut wire, 200, "text/plain", false, &[]).unwrap();
            cw.chunk(b"partial").unwrap();
            // dropped without finish(): terminal chunk still written
        }
        let mut rd = BufReader::new(&wire[..]);
        let resp = read_response(&mut rd).unwrap();
        assert_eq!(resp.body_str(), "partial");
    }
}

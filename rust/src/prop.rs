//! Property-testing mini-framework substrate (proptest is unavailable
//! offline).
//!
//! Closure-based generators over a seeded [`Pcg64`], a case runner that
//! reports the seed of a failing case, and greedy shrinking for the shapes
//! we actually test (integers shrink toward the low bound, vectors by
//! chunk removal then element shrinking). Used by the coordinator, kvcache,
//! sampling and tokenizer property tests.

use crate::rng::Pcg64;

/// A generator: produces a value from RNG, and knows how to shrink it.
pub struct Gen<T> {
    gen_fn: Box<dyn Fn(&mut Pcg64) -> T>,
    shrink_fn: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen_fn: impl Fn(&mut Pcg64) -> T + 'static,
        shrink_fn: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen { gen_fn: Box::new(gen_fn), shrink_fn: Box::new(shrink_fn) }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> T {
        (self.gen_fn)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink_fn)(v)
    }

    /// Map the generated value (shrinking degrades to no-op: mapping is not
    /// invertible in general).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        let g = self.gen_fn;
        Gen::new(move |rng| f(g(rng)), |_| Vec::new())
    }
}

/// usize in [lo, hi] inclusive; shrinks toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(hi >= lo);
    Gen::new(
        move |rng| rng.gen_range(lo, hi + 1),
        move |&v| {
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.sort_unstable();
            out.dedup();
            out.retain(|&x| x != v);
            out
        },
    )
}

/// f32 in [lo, hi); shrinks toward lo and the midpoint.
pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(
        move |rng| lo + rng.next_f32() * (hi - lo),
        move |&v| {
            let mid = lo + (v - lo) / 2.0;
            let mut out = vec![lo, mid];
            out.retain(|&x| (x - v).abs() > f32::EPSILON);
            out
        },
    )
}

/// Vector of length in [min_len, max_len], elements from `elem`.
pub fn vec_of<T: Clone + 'static>(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
    let elem = std::rc::Rc::new(elem);
    let elem_g = elem.clone();
    Gen::new(
        move |rng| {
            let n = rng.gen_range(min_len, max_len + 1);
            (0..n).map(|_| elem_g.sample(rng)).collect()
        },
        move |v: &Vec<T>| {
            let mut out: Vec<Vec<T>> = Vec::new();
            // Shrink by removing chunks (halves, then single elements).
            if v.len() > min_len {
                let half = (v.len() / 2).max(min_len);
                out.push(v[..half].to_vec());
                if v.len() > min_len {
                    out.push(v[..v.len() - 1].to_vec());
                    out.push(v[1..].to_vec());
                }
            }
            // Shrink one element at a time (first few positions).
            for i in 0..v.len().min(4) {
                for cand in elem.shrinks(&v[i]) {
                    let mut w = v.clone();
                    w[i] = cand;
                    out.push(w);
                }
            }
            out
        },
    )
}

/// Distribution over `n` outcomes: non-negative weights summing to 1.
/// The workhorse generator for the rejection-sampling properties.
pub fn distribution(n: usize) -> Gen<Vec<f32>> {
    Gen::new(
        move |rng| {
            // Dirichlet-ish via exp(normal) normalization; occasionally spiky.
            let spiky = rng.next_f64() < 0.3;
            let mut w: Vec<f32> = (0..n)
                .map(|_| {
                    let z = rng.next_normal() * if spiky { 4.0 } else { 1.0 };
                    (z as f32).exp()
                })
                .collect();
            let s: f32 = w.iter().sum();
            for x in &mut w {
                *x /= s;
            }
            w
        },
        move |v| {
            // Shrink toward uniform.
            let uniform = vec![1.0 / n as f32; n];
            if v.iter().zip(&uniform).any(|(a, b)| (a - b).abs() > 1e-6) {
                vec![uniform]
            } else {
                Vec::new()
            }
        },
    )
}

/// Outcome of a property check.
pub enum Check {
    Pass,
    Fail(String),
}

impl Check {
    pub fn that(cond: bool, msg: impl Into<String>) -> Check {
        if cond {
            Check::Pass
        } else {
            Check::Fail(msg.into())
        }
    }
}

/// Run `prop` over `cases` generated inputs; on failure, shrink greedily and
/// panic with the minimal counterexample found.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    seed: u64,
    prop: impl Fn(&T) -> Check,
) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Check::Fail(msg) = prop(&input) {
            // Greedy shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrinks(&best) {
                    budget -= 1;
                    if let Check::Fail(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  {best_msg}\n  minimal input: {best:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", &usize_in(0, 100), 200, 1, |&x| {
            Check::that(x + 1 > x, "increment grows")
        });
    }

    #[test]
    #[should_panic(expected = "minimal input: 51")]
    fn shrinks_to_boundary() {
        // Fails for x > 50; the minimal failing input is 51.
        check("le-50", &usize_in(0, 1000), 500, 2, |&x| {
            Check::that(x <= 50, format!("{x} > 50"))
        });
    }

    #[test]
    fn distribution_sums_to_one() {
        let g = distribution(32);
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            let d = g.sample(&mut rng);
            let s: f32 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = vec_of(usize_in(0, 9), 2, 6);
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            let v = g.sample(&mut rng);
            assert!(v.len() >= 2 && v.len() <= 6);
            assert!(v.iter().all(|&x| x < 10));
        }
    }
}

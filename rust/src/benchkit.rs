//! Benchmark statistics harness substrate (criterion is unavailable offline).
//!
//! Provides warmup, timed iteration batches, robust statistics (median, MAD,
//! IQR outlier trimming) and a compact report format. The figure benches in
//! `rust/benches/` use [`Bench`] for wall-clock rows and [`Stats`] directly
//! for derived metrics (block efficiency, MBSU).

use std::time::{Duration, Instant};

/// Robust summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Stats {
    pub fn from(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty(), "stats over empty sample");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: percentile(&xs, 0.50),
            p90: percentile(&xs, 0.90),
            p99: percentile(&xs, 0.99),
            max: xs[n - 1],
        }
    }

    /// Drop samples outside 1.5 IQR (criterion-style outlier trimming).
    pub fn from_trimmed(mut xs: Vec<f64>) -> Stats {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q1 = percentile(&xs, 0.25);
        let q3 = percentile(&xs, 0.75);
        let iqr = q3 - q1;
        let (lo, hi) = (q1 - 1.5 * iqr, q3 + 1.5 * iqr);
        let kept: Vec<f64> = xs.iter().copied().filter(|&x| x >= lo && x <= hi).collect();
        Stats::from(if kept.is_empty() { xs } else { kept })
    }
}

/// Sorted-input percentile with linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// A named wall-clock benchmark with warmup and trimmed statistics.
pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Bench { name: name.into(), warmup_iters: 3, measure_iters: 10 }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.measure_iters = n;
        self
    }

    /// Run `f` (one logical iteration per call) and report trimmed stats.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let stats = Stats::from_trimmed(samples);
        println!(
            "bench {:<42} n={:<3} p50={:>10} mean={:>10} p90={:>10}",
            self.name,
            stats.n,
            fmt_duration(stats.p50),
            fmt_duration(stats.mean),
            fmt_duration(stats.p90),
        );
        stats
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Measure a single closure's wall time.
pub fn time_it<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Write a machine-readable benchmark artifact (`BENCH_*.json`): pretty
/// JSON + trailing newline, written atomically (tmp + rename) so a
/// half-written artifact never lands in the perf trajectory CI uploads.
pub fn write_bench_json(path: &str, v: &crate::json::Value) -> crate::error::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, format!("{}\n", v.to_string_pretty())).map_err(crate::error::Error::Io)?;
    std::fs::rename(&tmp, path).map_err(crate::error::Error::Io)
}

/// Fixed-width table printer for the figure benches: the paper's rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.9) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn trimming_removes_outliers() {
        let mut xs = vec![1.0; 20];
        xs.push(1000.0);
        let s = Stats::from_trimmed(xs);
        assert!(s.max < 10.0, "outlier survived: {}", s.max);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0;
        let b = Bench::new("noop").warmup(1).iters(5);
        let s = b.run(|| count += 1);
        assert_eq!(count, 6);
        assert!(s.n >= 1);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2e-9).ends_with("ns"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with('s'));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn bench_json_roundtrips() {
        use crate::json::Value;
        let path = std::env::temp_dir().join("specd_bench_json_test.json");
        let path = path.to_str().unwrap().to_string();
        let v = Value::obj(vec![
            ("bench", Value::Str("t".into())),
            ("tokens_per_sec", Value::Num(123.5)),
        ]);
        write_bench_json(&path, &v).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(Value::parse(&text).unwrap(), v);
        std::fs::remove_file(&path).ok();
    }
}

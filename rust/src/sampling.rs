//! Sampling: logits post-processing and the Leviathan et al. modified
//! rejection rule — the correctness core of speculative decoding.
//!
//! The guarantee (property-tested in `rust/tests/spec_equivalence.rs` and
//! unit-tested here): for any draft distribution p and target distribution
//! q, the token emitted by `verify_block` is marginally distributed as q —
//! speculative decoding is *lossless* with respect to the target model.
//!
//! Greedy decoding (temperature 0) falls out as the one-hot limit: a draft
//! token is accepted iff it equals the target argmax, and the residual
//! collapses to the target argmax — no special-casing.

use crate::config::SamplingConfig;
use crate::rng::Pcg64;
use crate::tensor::{argmax, softmax_inplace, top_p_filter};

/// Convert a logits row to a probability vector under a sampling regime.
/// This must be applied identically to draft and target logits: the SD
/// correctness theorem is about the *post-processing-adjusted* distributions.
pub fn logits_to_probs(logits: &[f32], cfg: &SamplingConfig) -> Vec<f32> {
    let mut p = logits.to_vec();
    softmax_inplace(&mut p, cfg.temperature);
    top_p_filter(&mut p, cfg.top_p);
    p
}

/// Sample a token id from a probability vector.
pub fn sample_token(probs: &[f32], cfg: &SamplingConfig, rng: &mut Pcg64) -> u32 {
    if cfg.temperature <= 0.0 {
        argmax(probs) as u32
    } else {
        rng.categorical(probs) as u32
    }
}

/// Outcome of verifying one drafted block.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Number of draft tokens accepted (0..=gamma).
    pub accepted: usize,
    /// The token emitted *after* the accepted prefix: residual-sampled on
    /// rejection, or bonus-sampled from the gamma+1-th target distribution
    /// when everything was accepted.
    pub next_token: u32,
    /// True when all gamma draft tokens were accepted (next_token is the
    /// free bonus token).
    pub all_accepted: bool,
}

/// Modified rejection sampling over a drafted block (Leviathan et al. 2023).
///
/// * `draft_probs[j]` — p_j, the draft distribution the j-th token was
///   sampled from (post temperature/top-p).
/// * `target_probs[j]` — q_j for j in 0..gamma, plus `target_probs[gamma]`
///   = the bonus distribution used when every draft token is accepted.
/// * `tokens[j]` — the drafted token ids.
///
/// Accept t_j with probability min(1, q_j(t_j) / p_j(t_j)); at the first
/// rejection emit a token from the residual norm(max(q_j - p_j, 0)).
pub fn verify_block(
    draft_probs: &[Vec<f32>],
    target_probs: &[Vec<f32>],
    tokens: &[u32],
    rng: &mut Pcg64,
) -> VerifyOutcome {
    let gamma = tokens.len();
    assert_eq!(draft_probs.len(), gamma, "draft probs arity");
    assert!(target_probs.len() >= gamma + 1, "need gamma+1 target distributions");

    for j in 0..gamma {
        let t = tokens[j] as usize;
        let p = draft_probs[j][t].max(1e-20);
        let q = target_probs[j][t];
        let ratio = (q / p).min(1.0);
        if (rng.next_f64() as f32) < ratio {
            continue; // accepted
        }
        // Rejected at j: residual sample.
        let residual = residual_distribution(&draft_probs[j], &target_probs[j]);
        let next = rng.categorical(&residual) as u32;
        return VerifyOutcome { accepted: j, next_token: next, all_accepted: false };
    }
    // All accepted: bonus token from the gamma+1-th target distribution.
    let bonus = rng.categorical(&target_probs[gamma]) as u32;
    VerifyOutcome { accepted: gamma, next_token: bonus, all_accepted: true }
}

/// norm(max(q - p, 0)); falls back to q if the positive part has no mass
/// (p == q), matching kernels/ref.py::sd_accept.
pub fn residual_distribution(p: &[f32], q: &[f32]) -> Vec<f32> {
    let mut r: Vec<f32> = q.iter().zip(p).map(|(&qi, &pi)| (qi - pi).max(0.0)).collect();
    let z: f32 = r.iter().sum();
    if z > 1e-12 {
        for x in &mut r {
            *x /= z;
        }
        r
    } else {
        q.to_vec()
    }
}

/// Theoretical per-token acceptance probability 1 - TVD(p, q) — used by the
/// analytical-vs-empirical consistency test and the eval harness.
pub fn acceptance_probability(p: &[f32], q: &[f32]) -> f64 {
    p.iter().zip(q).map(|(&pi, &qi)| pi.min(qi) as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<f32> {
        vec![1.0 / n as f32; n]
    }

    fn onehot(n: usize, i: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        v[i] = 1.0;
        v
    }

    #[test]
    fn identical_distributions_always_accept() {
        let mut rng = Pcg64::new(1);
        let p = uniform(16);
        for _ in 0..200 {
            let tok = rng.next_below(16) as u32;
            let out = verify_block(
                &[p.clone(), p.clone()],
                &[p.clone(), p.clone(), p.clone()],
                &[tok, tok],
                &mut rng,
            );
            assert!(out.all_accepted);
            assert_eq!(out.accepted, 2);
        }
    }

    #[test]
    fn disjoint_supports_always_reject_and_emit_target() {
        let mut rng = Pcg64::new(2);
        let p = onehot(8, 0);
        let q = onehot(8, 5);
        for _ in 0..100 {
            let out = verify_block(&[p.clone()], &[q.clone(), q.clone()], &[0], &mut rng);
            assert_eq!(out.accepted, 0);
            assert_eq!(out.next_token, 5);
        }
    }

    #[test]
    fn greedy_limit_accepts_iff_argmax_matches() {
        let mut rng = Pcg64::new(3);
        // One-hots as produced by temperature-0 post-processing.
        let p = onehot(8, 3);
        let q_same = onehot(8, 3);
        let q_diff = onehot(8, 6);
        let a = verify_block(&[p.clone()], &[q_same.clone(), q_same], &[3], &mut rng);
        assert!(a.all_accepted);
        let b = verify_block(&[p], &[q_diff.clone(), q_diff], &[3], &mut rng);
        assert_eq!(b.accepted, 0);
        assert_eq!(b.next_token, 6);
    }

    #[test]
    fn residual_is_valid_distribution() {
        let p = vec![0.7, 0.2, 0.1];
        let q = vec![0.2, 0.5, 0.3];
        let r = residual_distribution(&p, &q);
        assert!((r.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert_eq!(r[0], 0.0); // q < p there
        assert!(r.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn residual_p_equals_q_falls_back_to_q() {
        let p = vec![0.5, 0.5];
        let r = residual_distribution(&p, &p);
        assert_eq!(r, p);
    }

    /// The lossless-ness theorem, empirically: marginal of emitted first
    /// token == q, regardless of p.
    #[test]
    fn output_distribution_matches_target() {
        let mut rng = Pcg64::new(4);
        let p = vec![0.6, 0.3, 0.1];
        let q = vec![0.1, 0.3, 0.6];
        let n = 60_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            // Draft samples from p; verify emits the first post-verification
            // token: accepted draft token, or the residual token.
            let tok = rng.categorical(&p) as u32;
            let out = verify_block(&[p.clone()], &[q.clone(), q.clone()], &[tok], &mut rng);
            let first = if out.accepted >= 1 { tok } else { out.next_token };
            counts[first as usize] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - q[i] as f64).abs() < 0.01,
                "token {i}: empirical {emp:.3} vs target {:.3}",
                q[i]
            );
        }
    }

    #[test]
    fn acceptance_rate_matches_one_minus_tvd() {
        let mut rng = Pcg64::new(5);
        let p = vec![0.5, 0.4, 0.1];
        let q = vec![0.3, 0.3, 0.4];
        let expected = acceptance_probability(&p, &q); // 0.3+0.3+0.1 = 0.7
        assert!((expected - 0.7).abs() < 1e-6);
        let n = 60_000;
        let mut acc = 0usize;
        for _ in 0..n {
            let tok = rng.categorical(&p) as u32;
            let out = verify_block(&[p.clone()], &[q.clone(), q.clone()], &[tok], &mut rng);
            acc += (out.accepted == 1) as usize;
        }
        let emp = acc as f64 / n as f64;
        assert!((emp - expected).abs() < 0.01, "empirical {emp} vs 1-TVD {expected}");
    }

    #[test]
    fn logits_pipeline_greedy_is_argmax_onehot() {
        let cfg = SamplingConfig::greedy();
        let p = logits_to_probs(&[0.0, 3.0, 1.0], &cfg);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
        let mut rng = Pcg64::new(6);
        assert_eq!(sample_token(&p, &cfg, &mut rng), 1);
    }

    #[test]
    fn top_p_pipeline_restricts_support() {
        let cfg = SamplingConfig::random(1.0, 0.5, 0);
        let p = logits_to_probs(&[2.0, 2.0, -10.0, -10.0], &cfg);
        assert_eq!(p[2], 0.0);
        assert_eq!(p[3], 0.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }
}

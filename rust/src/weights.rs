//! Reader for the `SPCD1` named-tensor weight format written by
//! `python/compile/aot.py::write_weights`.
//!
//! Layout (little-endian):
//! ```text
//! magic   6 bytes  "SPCD1\0"
//! count   u32      number of tensors
//! repeat count times:
//!   name_len u16, name bytes (utf-8)
//!   ndim     u8,  dims u32 * ndim
//!   data     f32 * prod(dims)
//! ```
//! Tensors appear in sorted-name order — the same canonical order the AOT
//! export flattens parameters with, so `tensors_in_order` can be handed
//! straight to the runtime as executable arguments.

use std::io::Read;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

const MAGIC: &[u8; 6] = b"SPCD1\x00";

#[derive(Debug)]
pub struct WeightsFile {
    names: Vec<String>,
    tensors: Vec<Tensor>,
    fingerprint: u64,
}

impl WeightsFile {
    pub fn load(path: &str) -> Result<WeightsFile> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Weights(format!("cannot read {path}: {e}")))?;
        Self::parse(&bytes).map_err(|e| match e {
            Error::Weights(m) => Error::Weights(format!("{path}: {m}")),
            other => other,
        })
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightsFile> {
        let fingerprint = fnv1a(bytes);
        let mut r = Cursor { bytes, pos: 0 };
        let magic = r.take(6)?;
        if magic != MAGIC {
            return Err(Error::Weights("bad magic (not an SPCD1 file)".into()));
        }
        let count = r.u32()? as usize;
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .map_err(|_| Error::Weights("non-utf8 tensor name".into()))?;
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let n: usize = dims.iter().product();
            let raw = r.take(n * 4)?;
            let mut data = vec![0f32; n];
            for (i, chunk) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            names.push(name);
            tensors.push(Tensor::new(dims, data)?);
        }
        if r.pos != bytes.len() {
            return Err(Error::Weights(format!(
                "{} trailing bytes after last tensor",
                bytes.len() - r.pos
            )));
        }
        // Canonical order check: names must be sorted (the AOT contract).
        if !names.windows(2).all(|w| w[0] < w[1]) {
            return Err(Error::Weights("tensor names not in sorted order".into()));
        }
        Ok(WeightsFile { names, tensors, fingerprint })
    }

    /// FNV-1a over the raw serialized bytes — a cheap content identity for
    /// the draft-lifecycle status surface (two bundles with the same
    /// fingerprint are byte-identical files; not a cryptographic digest).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.names.iter().position(|n| n == name).map(|i| &self.tensors[i])
    }

    /// Tensors in the canonical (sorted-name) order used as executable args.
    pub fn tensors_in_order(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Assert the file matches the manifest's `param_order`.
    pub fn check_order(&self, expected: &[String]) -> Result<()> {
        if self.names != expected {
            return Err(Error::Weights(format!(
                "parameter order mismatch: file has {:?}..., manifest expects {:?}...",
                &self.names[..self.names.len().min(3)],
                &expected[..expected.len().min(3)],
            )));
        }
        Ok(())
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            return Err(Error::Weights("unexpected end of file".into()));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// In-memory writer (tests + tooling parity with the python writer).
pub fn write(tensors: &[(String, Tensor)]) -> Vec<u8> {
    let mut sorted: Vec<&(String, Tensor)> = tensors.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(sorted.len() as u32).to_le_bytes());
    for (name, t) in sorted {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(t.shape().len() as u8);
        for &d in t.shape() {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &x in t.data() {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    out
}

/// Read a whole file through any reader (used by tests with in-memory data).
pub fn parse_reader<R: Read>(mut r: R) -> Result<WeightsFile> {
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    WeightsFile::parse(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<(String, Tensor)> {
        vec![
            ("b.w".to_string(), Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap()),
            ("a.norm".to_string(), Tensor::new(vec![3], vec![0.5, -0.5, 7.0]).unwrap()),
        ]
    }

    #[test]
    fn roundtrip() {
        let bytes = write(&sample());
        let wf = WeightsFile::parse(&bytes).unwrap();
        assert_eq!(wf.len(), 2);
        assert_eq!(wf.names(), &["a.norm".to_string(), "b.w".to_string()]);
        assert_eq!(wf.get("a.norm").unwrap().data(), &[0.5, -0.5, 7.0]);
        assert_eq!(wf.get("b.w").unwrap().shape(), &[2, 2]);
        assert_eq!(wf.param_count(), 7);
    }

    #[test]
    fn fingerprint_is_content_identity() {
        let bytes = write(&sample());
        let a = WeightsFile::parse(&bytes).unwrap().fingerprint();
        let b = WeightsFile::parse(&bytes).unwrap().fingerprint();
        assert_eq!(a, b, "same bytes, same fingerprint");
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        if let Ok(wf) = WeightsFile::parse(&flipped) {
            assert_ne!(wf.fingerprint(), a, "bit flip must change the fingerprint");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = write(&sample());
        bytes[0] = b'X';
        assert!(WeightsFile::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = write(&sample());
        assert!(WeightsFile::parse(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = write(&sample());
        bytes.extend_from_slice(&[0, 1, 2]);
        assert!(WeightsFile::parse(&bytes).is_err());
    }

    #[test]
    fn order_check() {
        let bytes = write(&sample());
        let wf = WeightsFile::parse(&bytes).unwrap();
        assert!(wf.check_order(&["a.norm".into(), "b.w".into()]).is_ok());
        assert!(wf.check_order(&["b.w".into(), "a.norm".into()]).is_err());
    }
}

//! Typed run configuration for the serving stack.
//!
//! A [`RunConfig`] is assembled from CLI flags (see `main.rs` / examples)
//! or parsed from a JSON file; it selects the artifact directory, the draft
//! model variant, the speculation depth gamma and the sampling regime per
//! task (the paper random-samples dolly at T=0.6/top-p 0.9 and greedy-
//! samples the summarization tasks, §3).

use crate::error::{Error, Result};
use crate::json::Value;

/// Sampling regime for one generation run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingConfig {
    /// Softmax temperature; `0.0` = greedy.
    pub temperature: f32,
    /// Nucleus mass; `1.0` disables top-p.
    pub top_p: f32,
    pub seed: u64,
}

impl SamplingConfig {
    pub fn greedy() -> Self {
        SamplingConfig { temperature: 0.0, top_p: 1.0, seed: 0 }
    }

    pub fn random(temperature: f32, top_p: f32, seed: u64) -> Self {
        SamplingConfig { temperature, top_p, seed }
    }

    /// The paper's per-task regimes (§3 Evaluation): dolly sampled at
    /// T=0.6/top-p=0.9, summarization + translation greedy.
    pub fn for_task(task: &str, seed: u64) -> Self {
        match task {
            "dolly" => SamplingConfig::random(0.6, 0.9, seed),
            _ => SamplingConfig { seed, ..SamplingConfig::greedy() },
        }
    }
}

/// Full serving run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact directory produced by `make artifacts`.
    pub artifacts_dir: String,
    /// Draft model name in the manifest (e.g. "draft_tvdpp_ckpt4").
    pub draft_model: String,
    /// Target model name in the manifest.
    pub target_model: String,
    /// Speculation depth gamma (the paper sweeps {3, 5}).
    pub gamma: usize,
    /// Max new tokens per request.
    pub max_new_tokens: usize,
    pub sampling: SamplingConfig,
    /// Scheduler: KV slot-pool capacity — the number of sequences resident
    /// at once, i.e. the serving memory budget
    /// ([`crate::kvcache::SlotPool`] is the sole admission gate).
    pub max_slots: usize,
    /// Scheduler: bounded admission queue length (backpressure).
    pub queue_depth: usize,
    /// Scheduler: max prompt tokens of admission prefill per scheduler
    /// iteration (`0` = unbounded — a whole wave drains before resident
    /// lanes run again). Bounding it interleaves chunked prefill with
    /// speculation blocks, trading TTFT for resident-lane ITL
    /// (Sarathi-style chunked-prefill scheduling).
    pub prefill_budget: usize,
    /// Lifecycle: post-swap guard window, in speculation blocks. While the
    /// window is open a drift-CUSUM fire, an accept rate below
    /// `swap_accept_floor`, or a draft-breaker open rolls the swap back to
    /// the last-known-good bundle. `0` adopts unguarded.
    pub swap_guard_blocks: usize,
    /// Lifecycle: minimum in-guard acceptance rate for a freshly swapped
    /// draft (evaluated once enough guard blocks have accumulated).
    /// `0.0` disables the floor.
    pub swap_accept_floor: f64,
    /// Scheduler: consecutive clean (non-quarantined) blocks after which a
    /// lane's salvage count resets, so transient faults spread over a long
    /// stream's lifetime cannot accumulate to the eviction cap. `0` keeps
    /// the pre-lifecycle behaviour (salvages never reset).
    pub salvage_reset_blocks: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: "artifacts".to_string(),
            draft_model: "draft_tvdpp_ckpt4".to_string(),
            target_model: "target".to_string(),
            gamma: 3,
            max_new_tokens: 48,
            sampling: SamplingConfig::greedy(),
            max_slots: 4,
            queue_depth: 64,
            prefill_budget: 0,
            swap_guard_blocks: 64,
            swap_accept_floor: 0.0,
            salvage_reset_blocks: 64,
        }
    }
}

impl RunConfig {
    pub fn validate(&self) -> Result<()> {
        if self.gamma == 0 || self.gamma > 5 {
            return Err(Error::msg(format!(
                "gamma={} outside the exported verify block (1..=5)",
                self.gamma
            )));
        }
        if self.max_slots == 0 {
            return Err(Error::msg("max_slots must be >= 1"));
        }
        if self.max_new_tokens == 0 {
            return Err(Error::msg("max_new_tokens must be >= 1"));
        }
        if !(0.0..=1.0).contains(&self.sampling.top_p) {
            return Err(Error::msg(format!("top_p={} not in [0,1]", self.sampling.top_p)));
        }
        if self.sampling.temperature < 0.0 {
            return Err(Error::msg("temperature must be >= 0"));
        }
        if !(0.0..=1.0).contains(&self.swap_accept_floor) {
            return Err(Error::msg(format!(
                "swap_accept_floor={} not in [0,1]",
                self.swap_accept_floor
            )));
        }
        Ok(())
    }

    /// Parse from a JSON object (file-based deployment configs).
    pub fn from_json(v: &Value) -> Result<RunConfig> {
        let d = RunConfig::default();
        let cfg = RunConfig {
            artifacts_dir: v
                .get("artifacts_dir")
                .as_str()
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            draft_model: v.get("draft_model").as_str().unwrap_or(&d.draft_model).to_string(),
            target_model: v.get("target_model").as_str().unwrap_or(&d.target_model).to_string(),
            gamma: v.get("gamma").as_usize().unwrap_or(d.gamma),
            max_new_tokens: v.get("max_new_tokens").as_usize().unwrap_or(d.max_new_tokens),
            sampling: SamplingConfig {
                temperature: v.get("temperature").as_f64().unwrap_or(0.0) as f32,
                top_p: v.get("top_p").as_f64().unwrap_or(1.0) as f32,
                seed: v.get("seed").as_i64().unwrap_or(0) as u64,
            },
            // "max_batch" is the pre-slot-pool name; still accepted so
            // existing deployment configs keep working.
            max_slots: v
                .get("max_slots")
                .as_usize()
                .or_else(|| v.get("max_batch").as_usize())
                .unwrap_or(d.max_slots),
            queue_depth: v.get("queue_depth").as_usize().unwrap_or(d.queue_depth),
            prefill_budget: v.get("prefill_budget").as_usize().unwrap_or(d.prefill_budget),
            swap_guard_blocks: v
                .get("swap_guard_blocks")
                .as_usize()
                .unwrap_or(d.swap_guard_blocks),
            swap_accept_floor: v
                .get("swap_accept_floor")
                .as_f64()
                .unwrap_or(d.swap_accept_floor),
            salvage_reset_blocks: v
                .get("salvage_reset_blocks")
                .as_usize()
                .map(|n| n as u32)
                .unwrap_or(d.salvage_reset_blocks),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn gamma_bounds_enforced() {
        let mut c = RunConfig::default();
        c.gamma = 8;
        assert!(c.validate().is_err());
        c.gamma = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn zero_max_new_rejected() {
        let mut c = RunConfig::default();
        c.max_new_tokens = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn task_sampling_matches_paper() {
        let dolly = SamplingConfig::for_task("dolly", 1);
        assert!((dolly.temperature - 0.6).abs() < 1e-6);
        assert!((dolly.top_p - 0.9).abs() < 1e-6);
        assert_eq!(SamplingConfig::for_task("xsum", 1).temperature, 0.0);
        assert_eq!(SamplingConfig::for_task("cnndm", 1).temperature, 0.0);
        assert_eq!(SamplingConfig::for_task("wmt", 1).temperature, 0.0);
    }

    #[test]
    fn from_json_overrides() {
        let v = Value::parse(
            r#"{"gamma": 5, "temperature": 0.6, "top_p": 0.9, "draft_model": "draft_base"}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&v).unwrap();
        assert_eq!(c.gamma, 5);
        assert_eq!(c.draft_model, "draft_base");
        assert!((c.sampling.temperature - 0.6).abs() < 1e-6);
    }

    #[test]
    fn zero_max_slots_rejected() {
        let mut c = RunConfig::default();
        c.max_slots = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_json_prefill_budget() {
        let c = RunConfig::from_json(&Value::parse(r#"{"prefill_budget": 64}"#).unwrap()).unwrap();
        assert_eq!(c.prefill_budget, 64);
        let c = RunConfig::from_json(&Value::parse("{}").unwrap()).unwrap();
        assert_eq!(c.prefill_budget, 0, "default: unbounded admission prefill");
    }

    #[test]
    fn lifecycle_knobs_parse_and_validate() {
        let c = RunConfig::from_json(
            &Value::parse(
                r#"{"swap_guard_blocks": 16, "swap_accept_floor": 0.25, "salvage_reset_blocks": 8}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.swap_guard_blocks, 16);
        assert!((c.swap_accept_floor - 0.25).abs() < 1e-9);
        assert_eq!(c.salvage_reset_blocks, 8);
        let d = RunConfig::from_json(&Value::parse("{}").unwrap()).unwrap();
        assert_eq!(d.swap_guard_blocks, 64);
        assert_eq!(d.swap_accept_floor, 0.0, "floor off by default");
        assert_eq!(d.salvage_reset_blocks, 64);
        let mut bad = RunConfig::default();
        bad.swap_accept_floor = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_json_max_slots_with_legacy_alias() {
        let c = RunConfig::from_json(&Value::parse(r#"{"max_slots": 8}"#).unwrap()).unwrap();
        assert_eq!(c.max_slots, 8);
        // Pre-slot-pool configs used "max_batch"; still honoured.
        let c = RunConfig::from_json(&Value::parse(r#"{"max_batch": 2}"#).unwrap()).unwrap();
        assert_eq!(c.max_slots, 2);
        // The new name wins when both are present.
        let c = RunConfig::from_json(&Value::parse(r#"{"max_slots": 3, "max_batch": 9}"#).unwrap())
            .unwrap();
        assert_eq!(c.max_slots, 3);
    }
}

//! Threaded execution substrate (tokio is unavailable offline).
//!
//! Provides the two primitives the coordinator needs:
//!
//! - [`ThreadPool`]: fixed worker pool with graceful shutdown, used for
//!   request handling off the scheduler thread.
//! - [`bounded`]: a bounded MPSC channel with blocking send — the
//!   backpressure mechanism for request admission (when the queue is full,
//!   producers block rather than piling up unbounded memory). `try_send`
//!   is the non-blocking variant behind the HTTP 429 path and
//!   `recv_timeout` bounds how long a connection handler waits on the
//!   scheduler.
//!
//! Everything is std-only: `Mutex` + `Condvar` underneath.
//!
//! Under `RUSTFLAGS="--cfg loom"` the sync primitives swap to `loom`'s
//! models so `rust/tests/loom_models.rs` can explore interleavings of the
//! channel and pool; default builds are untouched (see
//! `rust/vendor/loom/src/lib.rs` for the offline substitution contract).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[cfg(loom)]
use loom::sync::atomic::{AtomicBool, Ordering};
#[cfg(loom)]
use loom::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(loom)]
use loom::thread::JoinHandle;
#[cfg(not(loom))]
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(not(loom))]
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
use std::thread::JoinHandle;

// ---------------------------------------------------------------------------
// Bounded channel
// ---------------------------------------------------------------------------

struct ChannelInner<T> {
    queue: Mutex<ChannelState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> ChannelInner<T> {
    /// Poison-tolerant lock. A connection thread that panics while holding
    /// the queue mutex must not wedge every other producer and the
    /// scheduler behind a `PoisonError`: the channel state is only mutated
    /// by single push/pop/counter steps, so the state a panicking holder
    /// leaves behind is always internally consistent. Same idiom as
    /// `trace::lock_recorder`.
    fn lock_state(&self) -> MutexGuard<'_, ChannelState<T>> {
        self.queue.lock().unwrap_or_else(|p| p.into_inner())
    }
}

struct ChannelState<T> {
    items: VecDeque<T>,
    capacity: usize,
    senders: usize,
    receiver_alive: bool,
}

/// Sending half; cloneable. Dropping the last sender closes the channel.
pub struct Sender<T>(Arc<ChannelInner<T>>);

/// Receiving half (single consumer).
pub struct Receiver<T>(Arc<ChannelInner<T>>);

/// Error returned when the other side is gone.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

/// Error from [`Receiver::recv_timeout`].
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// Nothing arrived within the timeout; the channel is still open.
    Timeout,
    /// All senders dropped and the queue is drained.
    Closed,
}

impl<T> std::fmt::Debug for Sender<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Sender")
    }
}

impl<T> std::fmt::Debug for Receiver<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Receiver")
    }
}

pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0);
    let inner = Arc::new(ChannelInner {
        queue: Mutex::new(ChannelState {
            items: VecDeque::new(),
            capacity,
            senders: 1,
            receiver_alive: true,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (Sender(inner.clone()), Receiver(inner))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.lock_state().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.lock_state();
        st.senders -= 1;
        if st.senders == 0 {
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.0.lock_state().receiver_alive = false;
        self.0.not_full.notify_all();
    }
}

impl<T> Sender<T> {
    /// Blocking send — this is the admission backpressure.
    pub fn send(&self, item: T) -> Result<(), Closed> {
        // lint: fault-site(exec-send)
        if let Err(e) = crate::faults::inject(crate::faults::Site::ExecSend) {
            if e.is_transient() {
                // Transient intake glitch: absorbed by one backoff step —
                // the channel is lossless, the item just goes in late.
                crate::trace::retry(crate::faults::Site::ExecSend as u64, 1);
                std::thread::sleep(Duration::from_millis(1));
            } else {
                return Err(Closed);
            }
        }
        let mut st = self.0.lock_state();
        loop {
            if !st.receiver_alive {
                return Err(Closed);
            }
            if st.items.len() < st.capacity {
                st.items.push_back(item);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Whether the receiving half is still alive. The scheduler probes
    /// this every iteration so a disconnected client frees its batch slot
    /// even when no tokens are flowing toward it (exhausted `max_new`
    /// budget, capacity-finished block) — previously such sequences held
    /// their slot until natural completion.
    pub fn is_connected(&self) -> bool {
        self.0.lock_state().receiver_alive
    }

    /// Non-blocking send; gives the item back when full.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        // lint: fault-site(exec-try-send)
        if let Err(e) = crate::faults::inject(crate::faults::Site::ExecSend) {
            // Transient faults surface as backpressure (`Full`): the item
            // comes back and the caller's retry path (429 + Retry-After)
            // takes over. Permanent faults read as a dead receiver.
            return Err(if e.is_transient() {
                TrySendError::Full(item)
            } else {
                TrySendError::Closed(item)
            });
        }
        let mut st = self.0.lock_state();
        if !st.receiver_alive {
            return Err(TrySendError::Closed(item));
        }
        if st.items.len() >= st.capacity {
            return Err(TrySendError::Full(item));
        }
        st.items.push_back(item);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Items currently queued (the HTTP layer derives `Retry-After`
    /// hints from this depth).
    pub fn len(&self) -> usize {
        self.0.lock_state().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

impl<T> Receiver<T> {
    /// Blocking receive; Err(Closed) after all senders dropped and drained.
    pub fn recv(&self) -> Result<T, Closed> {
        let mut st = self.0.lock_state();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.0.not_full.notify_one();
                return Ok(item);
            }
            if st.senders == 0 {
                return Err(Closed);
            }
            st = self.0.not_empty.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Blocking receive with a deadline. `Timeout` leaves the channel
    /// usable; the HTTP handlers use this so a stalled scheduler can't pin
    /// a connection thread forever.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.0.lock_state();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.0.not_full.notify_one();
                return Ok(item);
            }
            if st.senders == 0 {
                return Err(RecvTimeoutError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, res) = self
                .0
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.senders == 0 {
                    return Err(RecvTimeoutError::Closed);
                }
                return Err(RecvTimeoutError::Timeout);
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.lock_state();
        let item = st.items.pop_front();
        if item.is_some() {
            self.0.not_full.notify_one();
        }
        item
    }

    /// Drain whatever is currently queued (scheduler batch pickup).
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.0.lock_state();
        let out: Vec<T> = st.items.drain(..).collect();
        if !out.is_empty() {
            self.0.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.0.lock_state().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Thread pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Worker spawn, split on `cfg(loom)`: real loom's `thread` module has no
/// `Builder`, so the named-thread nicety only exists on default builds.
#[cfg(not(loom))]
fn spawn_worker(i: usize, body: impl FnOnce() + Send + 'static) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("specd-worker-{i}"))
        .spawn(body)
        .expect("spawn worker")
}

#[cfg(loom)]
fn spawn_worker(_i: usize, body: impl FnOnce() + Send + 'static) -> JoinHandle<()> {
    loom::thread::spawn(body)
}

/// Fixed-size worker pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    shutting_down: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n_workers: usize, queue_cap: usize) -> Self {
        let (tx, rx) = bounded::<Job>(queue_cap.max(1));
        let rx = Arc::new(rx);
        let shutting_down = Arc::new(AtomicBool::new(false));
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                spawn_worker(i, move || {
                    while let Ok(job) = rx.recv() {
                        // A panicking job must not unwind the worker: the
                        // pool would silently shrink and, once the last
                        // worker died, every queued job (and its waiter)
                        // would strand. Job-level delivery of the panic is
                        // handled by `submit`/`map`; here we only contain it.
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, shutting_down }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        if self.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        let _ = self.tx.as_ref().expect("pool alive").send(Box::new(f));
    }

    /// Run `f` over each item, in parallel, returning results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let results = Arc::new(Mutex::new((0..n).map(|_| None).collect::<Vec<Option<R>>>()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for (i, item) in items.into_iter().enumerate() {
            let f = f.clone();
            let results = results.clone();
            let done = done.clone();
            self.execute(move || {
                // The done counter must advance even when `f` panics, or
                // the waiter below blocks forever on a job that will never
                // report (the pre-catch_unwind stranded-waiter bug).
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                if let Ok(v) = r {
                    results.lock().unwrap_or_else(|p| p.into_inner())[i] = Some(v);
                }
                let (lock, cv) = &*done;
                *lock.lock().unwrap_or_else(|p| p.into_inner()) += 1;
                cv.notify_one();
            });
        }
        let (lock, cv) = &*done;
        let mut count = lock.lock().unwrap_or_else(|p| p.into_inner());
        while *count < n {
            count = cv.wait(count).unwrap_or_else(|p| p.into_inner());
        }
        drop(count);
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .into_iter()
            .map(|r| r.expect("map job panicked (see worker stderr)"))
            .collect()
    }

    /// Submit one job and get a handle to its result. Unlike [`execute`]
    /// (fire-and-forget) the waiter always learns the outcome: a panic in
    /// `f` is caught and delivered as [`crate::Error::Worker`], and a job
    /// dropped unrun (pool shutdown) reads as a lost worker instead of a
    /// hang.
    ///
    /// [`execute`]: ThreadPool::execute
    pub fn submit<R, F>(&self, f: F) -> JobHandle<R>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        let (tx, rx) = bounded::<Result<R, String>>(1);
        self.execute(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                .map_err(|p| panic_message(p.as_ref()));
            let _ = tx.send(r);
        });
        JobHandle { rx }
    }
}

/// Best-effort stringification of a panic payload (`&str` and `String`
/// payloads — the overwhelmingly common cases — survive verbatim).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Waiter half of [`ThreadPool::submit`].
pub struct JobHandle<R> {
    rx: Receiver<Result<R, String>>,
}

impl<R> JobHandle<R> {
    /// Block until the job finishes. `Err(Error::Worker)` when the job
    /// panicked or was dropped unrun (pool shutdown / dead worker).
    pub fn wait(self) -> crate::Result<R> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(msg)) => Err(crate::Error::Worker(msg)),
            Err(Closed) => Err(crate::Error::Worker("job lost before running".to_string())),
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        drop(self.tx.take()); // close the channel; workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn channel_fifo() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
    }

    #[test]
    fn channel_close_on_sender_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(tx);
        assert_eq!(rx.recv(), Err(Closed));
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = bounded(1);
        tx.try_send(1).unwrap();
        assert!(matches!(tx.try_send(2), Err(TrySendError::Full(2))));
    }

    #[test]
    fn try_send_full_then_drains_and_accepts() {
        // The 429 path: a full queue rejects without consuming the item,
        // and the same item can be resubmitted after the receiver drains.
        let (tx, rx) = bounded(2);
        tx.try_send(10).unwrap();
        tx.try_send(11).unwrap();
        let back = match tx.try_send(12) {
            Err(TrySendError::Full(v)) => v,
            other => panic!("expected Full, got {other:?}"),
        };
        assert_eq!(rx.recv(), Ok(10));
        tx.try_send(back).unwrap();
        assert_eq!(rx.recv(), Ok(11));
        assert_eq!(rx.recv(), Ok(12));
    }

    #[test]
    fn try_send_after_receiver_drop_returns_closed() {
        let (tx, rx) = bounded(4);
        drop(rx);
        assert!(matches!(tx.try_send(7), Err(TrySendError::Closed(7))));
        // Blocking send must not hang either.
        assert_eq!(tx.send(8), Err(Closed));
    }

    #[test]
    fn is_connected_tracks_receiver_lifetime() {
        let (tx, rx) = bounded::<i32>(1);
        assert!(tx.is_connected());
        let tx2 = tx.clone();
        drop(rx);
        assert!(!tx.is_connected());
        assert!(!tx2.is_connected(), "all clones observe the hangup");
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = bounded(1);
        let t0 = std::time::Instant::now();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(30)),
            Err(RecvTimeoutError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(25), "returned too early");
        tx.send(5).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(30)), Ok(5));
    }

    #[test]
    fn recv_timeout_wakes_on_send() {
        let (tx, rx) = bounded(1);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(42).unwrap();
        });
        // Generous timeout: must return as soon as the item lands.
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)), Ok(42));
        t.join().unwrap();
    }

    #[test]
    fn recv_timeout_closed_channel() {
        let (tx, rx) = bounded::<i32>(1);
        drop(tx);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Closed)
        );
    }

    #[test]
    fn recv_timeout_drains_before_reporting_closed() {
        // Items queued before the last sender dropped must still be
        // delivered (close-then-drain semantics match recv()).
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Ok(1));
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Ok(2));
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Closed)
        );
    }

    #[test]
    fn backpressure_blocks_until_recv() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = std::thread::spawn(move || {
            tx.send(1).unwrap(); // blocks until main recv()s
            tx.send(2).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv(), Ok(0));
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        t.join().unwrap();
    }

    #[test]
    fn drain_picks_up_everything() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain(), vec![0, 1, 2, 3, 4]);
        assert!(rx.is_empty());
    }

    #[test]
    fn channel_survives_poisoned_lock() {
        // Regression for the specd-lint no-panic sweep: a producer that
        // panicked while holding the queue mutex used to poison it, after
        // which every send/recv on the channel panicked too. The channel
        // must stay fully usable.
        let (tx, rx) = bounded::<i32>(4);
        let tx2 = tx.clone();
        let _ = std::thread::spawn(move || {
            let _st = tx2.0.queue.lock().unwrap();
            panic!("poison the channel lock");
        })
        .join();
        assert!(tx.0.queue.is_poisoned(), "test setup: lock must be poisoned");
        tx.send(1).unwrap();
        tx.try_send(2).unwrap();
        assert!(tx.is_connected());
        assert_eq!(rx.len(), 2);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Timeout)
        );
        drop(tx);
        assert_eq!(rx.drain(), Vec::<i32>::new());
        assert_eq!(rx.recv(), Err(Closed));
    }

    #[test]
    fn pool_map_survives_panicking_job() {
        // A panicking job is contained by the worker's catch_unwind, and
        // the shared channel lock it touched on the way down must not end
        // up poisoned for the workers: a later map() over the same pool
        // still has to complete.
        let pool = ThreadPool::new(2, 16);
        let (tx, rx) = bounded::<()>(1);
        pool.execute(move || {
            let _tx = tx; // dropped on unwind => rx observes Closed
            panic!("poison the pool's shared state");
        });
        assert_eq!(rx.recv(), Err(Closed));
        let out = pool.map(vec![1usize, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn pool_submit_delivers_panic_and_stays_alive() {
        // The stranded-waiter regression: before the catch_unwind fix a
        // panicking job unwound its worker before any completion signal
        // fired, so the waiter blocked forever. Now the panic is caught,
        // delivered as Error::Worker, and the SAME pool (same workers)
        // must keep serving subsequent jobs.
        let pool = ThreadPool::new(1, 16); // one worker: it must survive
        let err = pool.submit(|| panic!("boom in job")).wait();
        match err {
            Err(crate::Error::Worker(msg)) => {
                assert!(msg.contains("boom in job"), "payload lost: {msg}")
            }
            other => panic!("expected Error::Worker, got {other:?}"),
        }
        assert_eq!(pool.submit(|| 21 * 2).wait().unwrap(), 42);
        // map() after a panic on the single worker also still completes.
        assert_eq!(pool.map(vec![1, 2], |x| x + 1), vec![2, 3]);
    }

    #[test]
    fn pool_map_counts_panicked_jobs_as_done() {
        // map()'s waiter must not hang when some jobs panic; the panic
        // surfaces on the caller (via the result expect), not as a hang.
        let pool = ThreadPool::new(2, 16);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(vec![0usize, 1, 2], |x| {
                assert!(x != 1, "injected job panic");
                x
            })
        }));
        assert!(caught.is_err(), "panicked job must propagate, not hang");
        // Pool still serves after the partial map.
        assert_eq!(pool.submit(|| 7).wait().unwrap(), 7);
    }

    #[test]
    fn sender_len_tracks_queue_depth() {
        let (tx, rx) = bounded(4);
        assert!(tx.is_empty());
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(tx.len(), 2);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(tx.len(), 1);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(3, 16);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful shutdown waits for all jobs
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(4, 16);
        let out = pool.map((0..20).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..20).map(|x| x * x).collect::<Vec<_>>());
    }
}

//! # specd — speculative decoding with direct-aligned draft models
//!
//! Rust serving coordinator (L3) for the three-layer reproduction of
//! *"Direct Alignment of Draft Model for Speculative Decoding with
//! Chat-Fine-Tuned LLMs"* (Goel et al., 2024).
//!
//! The request path is pure Rust: AOT-compiled HLO executables (lowered at
//! build time from the JAX/Pallas stack in `python/compile/`) are loaded via
//! the PJRT C API and driven by the speculative-decoding engine ([`spec`]),
//! the autoregressive baseline ([`baseline`]) and the continuous-batching
//! coordinator ([`coordinator`]).
//!
//! ## Layer map
//!
//! | module | role |
//! |---|---|
//! | [`runtime`] | PJRT client wrapper: load HLO text, compile, execute |
//! | [`weights`] | `SPCD1` named-tensor weight files -> device buffers |
//! | [`artifacts`] | manifest/vocab loading, artifact path resolution |
//! | [`tokenizer`] | SynthChat word-level tokenizer (shared vocab artifact) |
//! | [`kvcache`] | KV-slot pool with rollback-by-length semantics |
//! | [`sampling`] | temperature/top-p + Leviathan-style rejection sampling |
//! | [`spec`] | the draft-gamma-then-verify speculative decoding engine |
//! | [`batch`] | batch-stepped phase executor (lockstep across sequences) |
//! | [`baseline`] | plain autoregressive decoding (the paper's baseline) |
//! | [`coordinator`] | request queue, slot-pool admission, batch scheduler |
//! | [`datagen`] | `specd distill` bulk-generation driver (throughput mode) |
//! | [`dataset`] | sharded distillation dataset: writer/reader, checksums |
//! | [`http`] | HTTP/1.1 wire layer: parser, chunked/streaming writers |
//! | [`server`] | TCP front end (L4): `/v1/generate`, `/healthz`, `/metrics` |
//! | [`metrics`] | block efficiency, MBSU, token rate, latency histograms |
//! | [`faults`] | fault injection, dispatch retry, per-model circuit breakers |
//! | [`lifecycle`] | draft-bundle hot swap, guarded adoption, scheduler supervision |
//! | [`telemetry`] | windowed snapshot ring + acceptance-drift detection |
//! | [`trace`] | flight recorder: spans, Chrome-trace export, access log |
//! | [`workload`] | synthetic task generators (dolly/xsum/cnndm/wmt) |
//! | [`eval`] | figure/table harness used by `rust/benches/` |
//!
//! ## Substrates (crates unavailable offline, rebuilt in-repo)
//!
//! [`json`] (serde_json), [`cli`] (clap), [`rng`] (rand), [`exec`] (tokio's
//! threaded runtime), [`benchkit`] (criterion), [`prop`] (proptest).

pub mod artifacts;
pub mod baseline;
pub mod batch;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod dataset;
pub mod error;
pub mod eval;
pub mod exec;
pub mod faults;
pub mod http;
pub mod json;
pub mod kvcache;
pub mod lifecycle;
pub mod metrics;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod spec;
pub mod telemetry;
pub mod tensor;
pub mod tokenizer;
pub mod trace;
pub mod weights;
pub mod workload;

pub use error::{Error, Result};

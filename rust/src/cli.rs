//! Declarative CLI argument parser substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! defaults, required flags, and generated `--help` text. Used by the
//! `specd` launcher, the examples and the bench harnesses.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

#[derive(Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    takes_value: bool,
    required: bool,
}

/// Builder-style argument parser.
pub struct Args {
    program: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
    aliases: BTreeMap<&'static str, &'static str>,
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Args {
            program,
            about,
            specs: Vec::new(),
            aliases: BTreeMap::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Accept `--from` as another spelling of `--to` (renamed options keep
    /// working for existing scripts). The target spec must be declared.
    pub fn alias(mut self, from: &'static str, to: &'static str) -> Self {
        debug_assert!(self.specs.iter().any(|s| s.name == to), "alias target --{to} undeclared");
        self.aliases.insert(from, to);
        self
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            help,
            default: Some(default.to_string()),
            takes_value: true,
            required: false,
        });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: None, takes_value: true, required: true });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: None, takes_value: false, required: false });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [OPTIONS]\n\nOPTIONS:\n",
                            self.program, self.about, self.program);
        for spec in &self.specs {
            let left = if spec.takes_value {
                format!("--{} <value>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let dflt = match &spec.default {
                Some(d) => format!(" [default: {d}]"),
                None if spec.required => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  {left:<28} {}{dflt}\n", spec.help));
        }
        s.push_str("  --help                       print this help\n");
        s
    }

    /// Parse from process args (exits on --help).
    pub fn parse(self) -> Result<Parsed> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&argv)
    }

    pub fn parse_from(mut self, argv: &[String]) -> Result<Parsed> {
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                print!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let name: &str = self.aliases.get(name).copied().unwrap_or(name);
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| Error::Cli(format!("unknown option --{name}")))?
                    .clone();
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Cli(format!("--{name} needs a value")))?
                        }
                    };
                    self.values.insert(spec.name, value);
                } else {
                    if inline.is_some() {
                        return Err(Error::Cli(format!("--{name} takes no value")));
                    }
                    self.flags.insert(spec.name, true);
                }
            } else {
                self.positional.push(arg.clone());
            }
            i += 1;
        }
        // Defaults + required check.
        for spec in &self.specs {
            if spec.takes_value && !self.values.contains_key(spec.name) {
                match &spec.default {
                    Some(d) => {
                        self.values.insert(spec.name, d.clone());
                    }
                    None if spec.required => {
                        return Err(Error::Cli(format!("missing required --{}", spec.name)));
                    }
                    None => {}
                }
            }
        }
        Ok(Parsed { values: self.values, flags: self.flags, positional: self.positional })
    }
}

/// Result of parsing; typed getters panic-free via Result.
pub struct Parsed {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name).unwrap_or("")
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)
            .parse()
            .map_err(|_| Error::Cli(format!("--{name}: expected integer, got '{}'", self.str(name))))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)
            .parse()
            .map_err(|_| Error::Cli(format!("--{name}: expected number, got '{}'", self.str(name))))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name)
            .parse()
            .map_err(|_| Error::Cli(format!("--{name}: expected integer, got '{}'", self.str(name))))
    }

    /// Millisecond duration option; `0` means disabled (`None`). Used by
    /// the serving CLI for deadlines/timeouts.
    pub fn ms_opt(&self, name: &str) -> Result<Option<std::time::Duration>> {
        let ms = self.u64(name)?;
        Ok(if ms == 0 { None } else { Some(std::time::Duration::from_millis(ms)) })
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .opt("gamma", "5", "draft length")
            .opt("task", "dolly", "task")
            .parse_from(&argv(&["--gamma", "3"]))
            .unwrap();
        assert_eq!(p.usize("gamma").unwrap(), 3);
        assert_eq!(p.str("task"), "dolly");
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = Args::new("t", "test")
            .opt("n", "1", "count")
            .flag("verbose", "talk more")
            .parse_from(&argv(&["--n=42", "--verbose", "pos0"]))
            .unwrap();
        assert_eq!(p.usize("n").unwrap(), 42);
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["pos0"]);
    }

    #[test]
    fn required_enforced() {
        let r = Args::new("t", "test").req("model", "path").parse_from(&argv(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Args::new("t", "test").parse_from(&argv(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn aliases_resolve_to_target_spec() {
        let p = Args::new("t", "test")
            .opt("max-slots", "4", "pool size")
            .alias("max-batch", "max-slots")
            .parse_from(&argv(&["--max-batch", "8"]))
            .unwrap();
        assert_eq!(p.usize("max-slots").unwrap(), 8);
        // Equals syntax goes through the same resolution.
        let p = Args::new("t", "test")
            .opt("max-slots", "4", "pool size")
            .alias("max-batch", "max-slots")
            .parse_from(&argv(&["--max-batch=2"]))
            .unwrap();
        assert_eq!(p.usize("max-slots").unwrap(), 2);
    }

    #[test]
    fn ms_opt_zero_disables() {
        let p = Args::new("t", "test")
            .opt("timeout-ms", "0", "deadline")
            .parse_from(&argv(&[]))
            .unwrap();
        assert_eq!(p.ms_opt("timeout-ms").unwrap(), None);
        let p = Args::new("t", "test")
            .opt("timeout-ms", "0", "deadline")
            .parse_from(&argv(&["--timeout-ms", "2500"]))
            .unwrap();
        assert_eq!(p.ms_opt("timeout-ms").unwrap(), Some(std::time::Duration::from_millis(2500)));
    }

    #[test]
    fn lists() {
        let p = Args::new("t", "test")
            .opt("losses", "kld,tvd,tvdpp", "losses")
            .parse_from(&argv(&[]))
            .unwrap();
        assert_eq!(p.list("losses"), vec!["kld", "tvd", "tvdpp"]);
    }
}

//! Autoregressive baseline decoder — the comparator for every figure.
//!
//! One target-model decode call per emitted token; same sampling pipeline
//! as the speculative engine so token-rate ratios isolate the decoding
//! strategy, not the sampler.

use crate::config::SamplingConfig;
use crate::error::Result;
use crate::kvcache::SeqCache;
use crate::metrics::RateMeasurement;
use crate::rng::Pcg64;
use crate::runtime::{Entry, Model, SeqState};
use crate::sampling::{logits_to_probs, sample_token};
use crate::tokenizer::EOS;

/// Counters for an autoregressive run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArStats {
    pub generated: usize,
    pub target_calls: usize,
}

pub struct ArSession {
    pub seq: Vec<u32>,
    pub prompt_len: usize,
    cache: SeqCache<SeqState>,
    last_logits: Vec<f32>,
    pub stats: ArStats,
    pub finished: bool,
}

impl ArSession {
    pub fn generated(&self) -> &[u32] {
        &self.seq[self.prompt_len..]
    }
}

/// Plain autoregressive decoding with the target model.
pub struct ArDecoder<'a> {
    pub target: &'a Model,
}

impl<'a> ArDecoder<'a> {
    pub fn new(target: &'a Model) -> Self {
        ArDecoder { target }
    }

    pub fn start(&self, prompt: &[u32]) -> Result<ArSession> {
        let (state, last_logits) = self.target.prefill_prompt(prompt)?;
        let mut cache = SeqCache::new(state, self.target.max_seq());
        cache.advance(prompt.len())?;
        let pf = self.target.arch.block(Entry::Prefill);
        Ok(ArSession {
            seq: prompt.to_vec(),
            prompt_len: prompt.len(),
            cache,
            last_logits,
            stats: ArStats { generated: 0, target_calls: prompt.len().div_ceil(pf) },
            finished: false,
        })
    }

    /// Emit one token.
    pub fn step(&self, s: &mut ArSession, cfg: &SamplingConfig, rng: &mut Pcg64) -> Result<Option<u32>> {
        if s.finished || s.seq.len() + 1 >= self.target.max_seq() {
            s.finished = true;
            return Ok(None);
        }
        let probs = logits_to_probs(&s.last_logits, cfg);
        let tok = sample_token(&probs, cfg, rng);
        s.seq.push(tok);
        s.stats.generated += 1;
        if tok == EOS {
            s.finished = true;
            return Ok(Some(tok));
        }
        let state = s.cache.take_state()?;
        let (state, logits) = self.target.run(Entry::Decode, state, &[tok], s.cache.len())?;
        s.cache.put_state(state);
        s.cache.advance(1)?;
        s.stats.target_calls += 1;
        s.last_logits = logits;
        Ok(Some(tok))
    }

    /// Generate up to `max_new` tokens; returns tokens + wall-clock rate.
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
        cfg: &SamplingConfig,
        rng: &mut Pcg64,
    ) -> Result<(Vec<u32>, ArStats, RateMeasurement)> {
        let t0 = std::time::Instant::now();
        let mut s = self.start(prompt)?;
        for _ in 0..max_new {
            if self.step(&mut s, cfg, rng)?.is_none() {
                break;
            }
        }
        let elapsed = t0.elapsed();
        let out = s.generated().to_vec();
        let rate = RateMeasurement { new_tokens: out.len(), elapsed };
        Ok((out, s.stats, rate))
    }
}

//! The HTTP serving subsystem (L4): a TCP front end over the
//! continuous-batching [`crate::coordinator`].
//!
//! ```text
//!   TcpListener ── accept thread ──▶ exec::ThreadPool connection handlers
//!        │                                   │ parse (http::read_request)
//!        │ nonblocking poll +                │ tokenize / validate (400)
//!        │ shutdown flag                     │ try_send ──▶ admission queue
//!        ▼                                   │    └─ Full ⇒ 429 (backpressure)
//!   graceful drain                           ▼
//!   (stop accepting,              per-request Delta channel ◀── scheduler
//!    finish in-flight,            stream=1: one chunk per speculation block
//!    close admission queue)       else: wait for Delta::Done, one JSON body
//! ```
//!
//! Endpoints:
//!
//! * `POST /v1/generate` — JSON body `{"prompt": "...", "tokens": [...],
//!   "max_new": N, "task": "dolly", "temperature": T, "top_p": P,
//!   "seed": S, "chat": bool, "timeout_ms": MS}` (either `prompt` or
//!   `tokens`). Responds with tokens, decoded text and [`SpecStats`].
//!   With `?stream=1` (or `"stream": true`) the response is
//!   `Transfer-Encoding: chunked`, SSE-style: one `data: {...}\n\n` event
//!   per speculation block, then a terminal `data: {"done":true,...}`.
//! * `GET /healthz` — liveness probe (process up; always 200).
//! * `GET /readyz` — readiness probe: 200 only while the scheduler is
//!   actually decoding; 503 with a JSON reason while draining, during a
//!   swap quiesce, or while the supervisor rebuilds a panicked scheduler.
//! * `POST /v1/admin/reload-draft` — stage + hot-swap the draft bundle
//!   (202 accepted, 409 when a reload is already pending); requires
//!   `--admin-endpoints`, else 404.
//! * `GET /v1/admin/draft` — bundle-generation status: serving model,
//!   weights fingerprint, generation counter, swap/restart history.
//! * `GET /metrics` — Prometheus text format, live server-side aggregate.
//! * `GET /debug/stats` — latest telemetry snapshot + the windowed ring
//!   as JSON; `?stream=1` upgrades to an SSE stream pushing each newly
//!   sealed snapshot (requires `--debug-endpoints` and telemetry on).
//!
//! Status mapping: invalid request 400, unknown path 404, wrong method
//! 405, deadline exceeded 408 ([`crate::coordinator::ERR_DEADLINE`]),
//! oversized body 413, admission queue full 429, header overflow 431,
//! engine failure 500, chunked request bodies 501, scheduler offline 503,
//! scheduler stall 504.
//!
//! The server owns no model state: it bridges into the scheduler through
//! the bounded channels from [`crate::exec`], so it can be tested against
//! a mock scheduler with no artifacts (see
//! `rust/tests/server_integration.rs`).

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::SamplingConfig;
use crate::coordinator::{Delta, Request, ERR_DEADLINE};
use crate::error::{Error, Result};
use crate::exec::{self, RecvTimeoutError, Sender, ThreadPool, TrySendError};
use crate::http::{self, ChunkedWriter, HttpError, HttpRequest, Limits};
use crate::json::{ObjWriter, Value};
use crate::metrics::{SchedulerGauges, ServeMetrics, SpecStats};
use crate::tokenizer::Tokenizer;

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Connection-handler threads (requests in flight concurrently at the
    /// HTTP layer; the scheduler's slot pool bounds decode concurrency).
    pub n_workers: usize,
    pub limits: Limits,
    /// `max_new` when the request doesn't specify one.
    pub default_max_new: usize,
    /// Hard cap on client-requested `max_new`.
    pub max_new_ceiling: usize,
    /// Deadline applied when the request doesn't carry `timeout_ms`.
    pub default_deadline: Option<Duration>,
    /// Close keep-alive connections idle longer than this.
    pub keep_alive_idle: Duration,
    /// Max wait for the *next* scheduler event before declaring a stall
    /// (504). Progress resets the clock, and the timer only arms once the
    /// request is admitted ([`Delta::Started`]) — time spent queued is
    /// bounded by the client's `timeout_ms`, not by this.
    pub scheduler_wait: Duration,
    /// Live scheduler gauges (slot-pool occupancy, per-phase timing),
    /// shared with the scheduler thread and appended to `GET /metrics`
    /// when present.
    pub scheduler_gauges: Option<Arc<SchedulerGauges>>,
    /// Expose `GET /debug/trace` (flight-recorder ring as Chrome trace
    /// JSON) and `GET /debug/requests/<id>` (one request's lifecycle
    /// timeline). Off by default: the endpoints 404 unless the operator
    /// opts in (`--debug-endpoints`).
    pub debug_endpoints: bool,
    /// Windowed telemetry ring shared with the scheduler thread. Serves
    /// `GET /debug/stats` (+ SSE) and appends the `specd_health_*`
    /// families to `GET /metrics` when present.
    pub telemetry: Option<Arc<crate::telemetry::Telemetry>>,
    /// Fault-domain resilience state (per-model circuit breakers, fault
    /// and salvage counters), shared with the scheduler thread; appends
    /// the `specd_breaker_state` / `specd_degraded_mode` /
    /// `specd_faults_injected_total` / `specd_dispatch_retries_total` /
    /// `specd_lanes_salvaged_total` families to `GET /metrics`.
    pub resilience: Option<Arc<crate::faults::Resilience>>,
    /// Draft-lifecycle control plane shared with the supervisor thread:
    /// drives `/readyz`, the admin reload/status endpoints, and appends
    /// the `specd_draft_generation` / `specd_draft_swaps_total` /
    /// `specd_scheduler_restarts_total` families to `GET /metrics`.
    pub lifecycle: Option<Arc<crate::lifecycle::Lifecycle>>,
    /// Expose the mutating `POST /v1/admin/reload-draft` endpoint (and
    /// the status surface). Off by default: the endpoints 404 unless the
    /// operator opts in (`--admin-endpoints`).
    pub admin_endpoints: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8080".to_string(),
            n_workers: 8,
            limits: Limits::default(),
            default_max_new: 48,
            max_new_ceiling: 256,
            default_deadline: None,
            keep_alive_idle: Duration::from_secs(10),
            scheduler_wait: Duration::from_secs(120),
            scheduler_gauges: None,
            debug_endpoints: false,
            telemetry: None,
            resilience: None,
            lifecycle: None,
            admin_endpoints: false,
        }
    }
}

// ---------------------------------------------------------------------------
// Shared live state (rendered by /metrics)
// ---------------------------------------------------------------------------

#[derive(Default)]
pub struct ServerState {
    next_id: AtomicU64,
    in_flight: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
    rejected_busy: AtomicU64,
    timeouts_408: AtomicU64,
    /// Per-request aggregates folded in as generations complete.
    agg: Mutex<ServeMetrics>,
}

impl ServerState {
    fn count_status(&self, code: u16) {
        match code {
            200..=299 => &self.responses_2xx,
            408 => {
                self.timeouts_408.fetch_add(1, Ordering::Relaxed);
                &self.responses_4xx
            }
            429 => {
                self.rejected_busy.fetch_add(1, Ordering::Relaxed);
                &self.responses_4xx
            }
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    /// The metrics aggregate, poison-tolerant: a handler thread that
    /// panicked while holding the lock must not take every later
    /// request's metrics merge (and the /metrics endpoint) down with it.
    /// Same idiom as `trace::lock_recorder`.
    fn agg(&self) -> std::sync::MutexGuard<'_, ServeMetrics> {
        self.agg.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn merge_completed(&self, m: &ServeMetrics) {
        self.agg().merge(m);
    }

    /// Snapshot of the generation aggregate (tests / final report).
    pub fn aggregate_report(&self) -> String {
        self.agg().report()
    }

    pub fn completed_requests(&self) -> usize {
        self.agg().total_requests
    }

    /// Full Prometheus exposition: HTTP-layer counters + the generation
    /// aggregate from [`ServeMetrics::prometheus_text`].
    pub fn prometheus(&self) -> String {
        use crate::metrics::{prom_counter, prom_gauge};
        let mut s = String::new();
        prom_counter(&mut s, "specd_http_responses_2xx_total", "HTTP responses with 2xx status.",
                     self.responses_2xx.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_http_responses_4xx_total", "HTTP responses with 4xx status.",
                     self.responses_4xx.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_http_responses_5xx_total", "HTTP responses with 5xx status.",
                     self.responses_5xx.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_http_rejected_busy_total",
                     "Requests rejected 429 (queue full).",
                     self.rejected_busy.load(Ordering::Relaxed) as f64);
        prom_counter(&mut s, "specd_http_timeouts_total", "Requests answered 408 (deadline).",
                     self.timeouts_408.load(Ordering::Relaxed) as f64);
        prom_gauge(&mut s, "specd_http_in_flight", "Requests currently being handled.",
                   self.in_flight.load(Ordering::Relaxed) as f64);
        s.push_str(&self.agg().prometheus_text());
        s
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

struct Inner {
    cfg: ServerConfig,
    tokenizer: Arc<Tokenizer>,
    state: Arc<ServerState>,
    shutdown: Arc<AtomicBool>,
}

/// A running HTTP server. Dropping (or calling [`Server::shutdown`])
/// stops accepting, drains in-flight connections, then closes its side of
/// the admission queue so the coordinator can drain and exit.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind `cfg.addr` and serve in background threads. `req_tx` feeds the
    /// coordinator's bounded admission queue; it is consumed so the queue
    /// closes exactly when the server has fully stopped.
    pub fn start(
        cfg: ServerConfig,
        tokenizer: Arc<Tokenizer>,
        req_tx: Sender<Request>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::msg(format!("bind {}: {e}", cfg.addr)))?;
        let addr = listener.local_addr().map_err(Error::Io)?;
        listener.set_nonblocking(true).map_err(Error::Io)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ServerState::default());
        let inner = Arc::new(Inner {
            cfg: cfg.clone(),
            tokenizer,
            state: state.clone(),
            shutdown: shutdown.clone(),
        });

        let accept_shutdown = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name("specd-accept".to_string())
            .spawn(move || {
                let pool = ThreadPool::new(cfg.n_workers, cfg.n_workers * 2);
                while !accept_shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let inner = inner.clone();
                            let req_tx = req_tx.clone();
                            pool.execute(move || handle_connection(stream, inner, req_tx));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(20)),
                    }
                }
                // pool drops here: waits for in-flight connections, then the
                // last req_tx clone drops and the admission queue closes.
            })
            .map_err(Error::Io)?;

        Ok(Server { addr, shutdown, accept_thread: Some(accept_thread), state })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// Graceful drain: stop accepting, finish in-flight requests, close
    /// the admission queue. Idempotent.
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

/// Socket read timeout: the granularity at which idle keep-alive loops
/// notice the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(250);

/// Socket write timeout: bounds how long a stalled client (full TCP send
/// buffer, reader gone) can pin a worker thread — without it, graceful
/// shutdown could hang on a dead streaming peer.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Pre-admission wait granularity: while a request is still queued the
/// handler wakes at this tick to notice server shutdown, so a wedged
/// scheduler cannot deadlock the graceful drain.
const ADMIT_TICK: Duration = Duration::from_millis(500);

fn handle_connection(stream: TcpStream, inner: Arc<Inner>, req_tx: Sender<Request>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut idle_since = Instant::now();

    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match http::read_request(&mut reader, &inner.cfg.limits, Some(&mut writer)) {
            Ok(req) => {
                inner.state.in_flight.fetch_add(1, Ordering::Relaxed);
                let keep = req.keep_alive() && !inner.shutdown.load(Ordering::SeqCst);
                let keep = route(&req, keep, &mut writer, &inner, &req_tx) && keep;
                inner.state.in_flight.fetch_sub(1, Ordering::Relaxed);
                if !keep {
                    break;
                }
                idle_since = Instant::now();
            }
            Err(HttpError::TimedOut { started: false }) => {
                if idle_since.elapsed() > inner.cfg.keep_alive_idle {
                    break;
                }
            }
            Err(HttpError::TimedOut { started: true }) => break, // stalled client
            Err(HttpError::Eof) => break,
            Err(HttpError::TooLarge(what)) => {
                let code = if what == "body" { 413 } else { 431 };
                let _ = respond_error(&inner.state, &mut writer, code, false,
                                      &format!("{what} exceeds limit"));
                break;
            }
            Err(HttpError::Unsupported(what)) => {
                let _ = respond_error(&inner.state, &mut writer, 501, false, what);
                break;
            }
            Err(HttpError::Malformed(m)) => {
                let _ = respond_error(&inner.state, &mut writer, 400, false, &m);
                break;
            }
            Err(HttpError::Io(_)) => break,
        }
    }
}

/// Route one request; returns whether the connection may continue.
fn route(
    req: &HttpRequest,
    keep: bool,
    w: &mut TcpStream,
    inner: &Inner,
    req_tx: &Sender<Request>,
) -> bool {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            respond(&inner.state, w, 200, "text/plain", b"ok\n", keep, &[])
        }
        ("GET", "/readyz") => readyz(keep, w, inner),
        ("POST", "/v1/admin/reload-draft") if inner.cfg.admin_endpoints => {
            admin_reload(req, keep, w, inner)
        }
        ("GET", "/v1/admin/draft") if inner.cfg.admin_endpoints => admin_status(keep, w, inner),
        ("GET", "/metrics") => {
            let mut text = inner.state.prometheus();
            if let Some(g) = &inner.cfg.scheduler_gauges {
                text.push_str(&g.prometheus_text());
            }
            if let Some(t) = &inner.cfg.telemetry {
                text.push_str(&t.prometheus_text());
            }
            if let Some(r) = &inner.cfg.resilience {
                text.push_str(&r.prometheus_text());
            }
            if let Some(lc) = &inner.cfg.lifecycle {
                text.push_str(&lc.prometheus_text());
            }
            respond(&inner.state, w, 200, "text/plain; version=0.0.4", text.as_bytes(), keep, &[])
        }
        ("POST", "/v1/generate") => generate(req, keep, w, inner, req_tx),
        // Debug endpoints 404 (fall through to the catch-all) unless the
        // operator opted in: trace rings leak prompts' shape and timing.
        ("GET", "/debug/trace") if inner.cfg.debug_endpoints => {
            let body = crate::trace::chrome_trace_json();
            respond(&inner.state, w, 200, "application/json", body.as_bytes(), keep, &[])
        }
        ("GET", "/debug/stats") if inner.cfg.debug_endpoints => match &inner.cfg.telemetry {
            Some(t) if req.query_flag("stream") => stream_stats(keep, w, inner, t),
            Some(t) => {
                let body = t.stats_json();
                respond(&inner.state, w, 200, "application/json", body.as_bytes(), keep, &[])
            }
            None => respond_error(&inner.state, w, 404, keep, "telemetry disabled"),
        },
        ("GET", p) if inner.cfg.debug_endpoints && p.starts_with("/debug/requests/") => {
            let seg = &p["/debug/requests/".len()..];
            let timeline = crate::trace::resolve_request_id(seg)
                .and_then(crate::trace::request_timeline_json);
            match timeline {
                Some(body) => {
                    respond(&inner.state, w, 200, "application/json", body.as_bytes(), keep, &[])
                }
                None => respond_error(&inner.state, w, 404, keep, "unknown request"),
            }
        }
        (_, "/healthz" | "/readyz" | "/metrics" | "/v1/generate") => {
            respond_error(&inner.state, w, 405, keep, "method not allowed")
        }
        (_, "/v1/admin/reload-draft" | "/v1/admin/draft") if inner.cfg.admin_endpoints => {
            respond_error(&inner.state, w, 405, keep, "method not allowed")
        }
        _ => respond_error(&inner.state, w, 404, keep, "not found"),
    }
}

// ---------------------------------------------------------------------------
// /readyz + draft-lifecycle admin surface
// ---------------------------------------------------------------------------

/// `GET /readyz`: 200 while the scheduler is decoding, 503 with a JSON
/// reason otherwise. Distinct from `/healthz` (pure liveness) so rolling
/// restarts and swap quiesces steer traffic without killing the process.
fn readyz(keep: bool, w: &mut TcpStream, inner: &Inner) -> bool {
    let reason = if inner.shutdown.load(Ordering::SeqCst) {
        Some("draining")
    } else {
        match &inner.cfg.lifecycle {
            Some(lc) => {
                let st = lc.state();
                if st.ready() {
                    None
                } else {
                    Some(st.name())
                }
            }
            // No lifecycle attached (tests, bench harnesses): readiness
            // degenerates to liveness.
            None => None,
        }
    };
    match reason {
        None => respond(&inner.state, w, 200, "text/plain", b"ready\n", keep, &[]),
        Some(r) => {
            let body = ObjWriter::new().bool("ready", false).str("reason", r).finish();
            respond_with(&inner.state, w, 503, keep, body, &[("retry-after", "1")])
        }
    }
}

/// `POST /v1/admin/reload-draft`: arm the one-deep reload mailbox. The
/// scheduler picks it up at the next block boundary; staging, validation
/// and the swap all happen off the HTTP path, so this answers 202
/// (accepted, in progress) — poll `GET /v1/admin/draft` for the outcome.
fn admin_reload(req: &HttpRequest, keep: bool, w: &mut TcpStream, inner: &Inner) -> bool {
    let Some(lc) = &inner.cfg.lifecycle else {
        return respond_error(&inner.state, w, 503, keep, "lifecycle control plane not attached");
    };
    // Optional JSON body: {"model": "<manifest name>"}. Default: re-stage
    // the serving model's name (in-place bundle re-export).
    let model = if req.body.is_empty() {
        None
    } else {
        match Value::parse(&req.body_str()) {
            Ok(v) => match v.get("model") {
                Value::Null => None,
                m => match m.as_str() {
                    Some(s) => Some(s.to_string()),
                    None => {
                        return respond_error(&inner.state, w, 400, keep, "'model' must be a string")
                    }
                },
            },
            Err(e) => {
                return respond_error(&inner.state, w, 400, keep, &format!("invalid json: {e}"))
            }
        }
    };
    let model = model.unwrap_or_else(|| lc.serving().0);
    if !lc.request_reload(crate::lifecycle::ReloadSpec { model: model.clone() }) {
        return respond_error(&inner.state, w, 409, keep, "a reload is already pending");
    }
    let body = ObjWriter::new()
        .bool("accepted", true)
        .str("model", &model)
        .num("generation", lc.generation() as f64)
        .finish();
    respond_with(&inner.state, w, 202, keep, body, &[])
}

/// `GET /v1/admin/draft`: the bundle-generation status surface.
fn admin_status(keep: bool, w: &mut TcpStream, inner: &Inner) -> bool {
    let Some(lc) = &inner.cfg.lifecycle else {
        return respond_error(&inner.state, w, 503, keep, "lifecycle control plane not attached");
    };
    let (model, fingerprint, params) = lc.serving();
    let (adopted, rejected, rolled_back, restarts) = lc.counters();
    let mut o = ObjWriter::new()
        .str("state", lc.state().name())
        .num("generation", lc.generation() as f64)
        .str("model", &model)
        .str("fingerprint", &format!("{fingerprint:016x}"))
        .num("params", params as f64)
        .num("swaps_adopted", adopted as f64)
        .num("swaps_rejected", rejected as f64)
        .num("swaps_rolled_back", rolled_back as f64)
        .num("scheduler_restarts", restarts as f64);
    if let Some(p) = lc.pending_reload() {
        o = o.str("pending_reload", &p);
    }
    if let Some(s) = lc.last_swap() {
        let swap = ObjWriter::new()
            .str("model", &s.model)
            .str("outcome", s.outcome)
            .str("detail", &s.detail)
            .num("generation", s.generation as f64)
            .finish();
        o = o.raw("last_swap", &swap);
    }
    respond_with(&inner.state, w, 200, keep, o.finish(), &[])
}

// ---------------------------------------------------------------------------
// /v1/generate
// ---------------------------------------------------------------------------

struct GenSpec {
    prompt: Vec<u32>,
    max_new: usize,
    sampling: SamplingConfig,
    deadline: Option<Duration>,
    stream: bool,
    /// Telemetry task tag (the request's `"task"` field, when present).
    tag: Option<String>,
}

/// Parse and validate the request body; Err(message) maps to 400.
fn parse_gen_spec(
    req: &HttpRequest,
    inner: &Inner,
    id: u64,
) -> std::result::Result<GenSpec, String> {
    let body = if req.body.is_empty() {
        Value::Obj(Default::default())
    } else {
        Value::parse(&req.body_str()).map_err(|e| format!("invalid json: {e}"))?
    };

    let mut prompt: Vec<u32> = match body.get("tokens") {
        Value::Arr(a) => a
            .iter()
            .map(|v| v.as_usize().map(|t| t as u32).ok_or_else(|| "bad token id".to_string()))
            .collect::<std::result::Result<_, _>>()?,
        Value::Null => match body.get("prompt").as_str() {
            Some(text) => inner.tokenizer.encode(text).map_err(|e| e.to_string())?,
            None => return Err("body needs 'prompt' (string) or 'tokens' (array)".to_string()),
        },
        _ => return Err("'tokens' must be an array".to_string()),
    };
    if body.get("chat").as_bool().unwrap_or(false) {
        prompt = inner.tokenizer.chat_prompt(&prompt);
    }
    if prompt.is_empty() {
        return Err("empty prompt".to_string());
    }
    // Range-check client-supplied ids here so garbage is a 400, not a 500
    // from the engine after burning an admission slot.
    let vocab = inner.tokenizer.vocab_size() as u32;
    if let Some(&bad) = prompt.iter().find(|&&t| t >= vocab) {
        return Err(format!("token id {bad} out of range (vocab size {vocab})"));
    }

    let max_new = body
        .get("max_new")
        .as_usize()
        .unwrap_or(inner.cfg.default_max_new)
        .min(inner.cfg.max_new_ceiling.max(1))
        .max(1);

    // Default seed: a multiplicative mix of the id, NOT the id itself —
    // the coordinator derives its stream from `seed ^ id`, which would
    // cancel to 0 for every request and make all unseeded sampled
    // requests identical.
    let seed = body
        .get("seed")
        .as_i64()
        .map(|s| s as u64)
        .unwrap_or_else(|| id.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1));
    let mut sampling = match body.get("task").as_str() {
        Some(task) => SamplingConfig::for_task(task, seed),
        None => SamplingConfig { seed, ..SamplingConfig::greedy() },
    };
    if let Some(t) = body.get("temperature").as_f64() {
        sampling.temperature = t as f32;
    }
    if let Some(p) = body.get("top_p").as_f64() {
        sampling.top_p = p as f32;
    }
    if !(0.0..=1.0).contains(&sampling.top_p) || sampling.temperature < 0.0 {
        return Err("invalid sampling parameters".to_string());
    }

    let deadline = match body.get("timeout_ms").as_usize() {
        Some(0) => return Err("timeout_ms must be positive".to_string()),
        Some(ms) => Some(Duration::from_millis(ms as u64)),
        None => inner.cfg.default_deadline,
    };
    let stream = req.query_flag("stream") || body.get("stream").as_bool().unwrap_or(false);
    let tag = body.get("task").as_str().map(|t| t.to_string());
    Ok(GenSpec { prompt, max_new, sampling, deadline, stream, tag })
}

fn generate(
    req: &HttpRequest,
    keep: bool,
    w: &mut TcpStream,
    inner: &Inner,
    req_tx: &Sender<Request>,
) -> bool {
    let id = inner.state.next_id.fetch_add(1, Ordering::Relaxed);
    // Client-facing request ID, minted at the HTTP edge: honor a
    // reasonable `X-Request-Id` so client-side correlation survives,
    // otherwise derive one from the internal id. Echoed on every response
    // (header + error bodies + SSE preamble) and mapped into the trace.
    let rid = match req.header("x-request-id") {
        Some(h) if !h.is_empty() && h.len() <= crate::trace::MAX_RID_LEN => h.to_string(),
        _ => format!("req-{id}"),
    };
    crate::trace::register_rid(id, &rid);
    let spec = match parse_gen_spec(req, inner, id) {
        Ok(s) => s,
        Err(msg) => return respond_error_rid(&inner.state, w, 400, keep, &msg, &rid),
    };
    // Chunked transfer encoding doesn't exist in HTTP/1.0; refuse rather
    // than feed the client framing it cannot parse.
    if spec.stream && !req.http11 {
        return respond_error_rid(&inner.state, w, 400, keep, "streaming requires HTTP/1.1", &rid);
    }

    // Channel sized so the scheduler never blocks on a slow client:
    // Started + one Tokens delta per block (each emits >= 1 token) +
    // the terminal Done.
    let (ev_tx, ev_rx) = exec::bounded::<Delta>(spec.max_new + 3);
    let request = Request {
        id,
        prompt: spec.prompt,
        max_new: spec.max_new,
        sampling: spec.sampling,
        deadline: spec.deadline,
        submitted: Some(Instant::now()),
        events: Some(ev_tx),
        tag: spec.tag,
    };

    // Admission control: never block the HTTP thread on a full queue.
    match req_tx.try_send(request) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            let ra = retry_after_secs(inner).to_string();
            return respond_with(
                &inner.state, w, 429, keep,
                ObjWriter::new()
                    .str("error", "server busy: admission queue full")
                    .str("request_id", &rid)
                    .finish(),
                &[("retry-after", &ra), ("x-request-id", &rid)],
            );
        }
        Err(TrySendError::Closed(_)) => {
            return respond_error_retry(&inner.state, w, 503, keep, "scheduler offline", &rid,
                                       DRAIN_RETRY_AFTER_SECS);
        }
    }

    if spec.stream {
        stream_response(id, keep, w, inner, &ev_rx, &rid)
    } else {
        unary_response(id, keep, w, inner, &ev_rx, &rid)
    }
}

/// Wait for the terminal event and answer with one JSON body.
fn unary_response(
    id: u64,
    keep: bool,
    w: &mut TcpStream,
    inner: &Inner,
    ev_rx: &exec::Receiver<Delta>,
    rid: &str,
) -> bool {
    let mut admitted = false;
    let mut drain_waited = Duration::ZERO;
    loop {
        let wait = if admitted { inner.cfg.scheduler_wait } else { ADMIT_TICK };
        match ev_rx.recv_timeout(wait) {
            Ok(Delta::Started) => admitted = true,
            // Interim deltas only matter for streaming; the terminal
            // Response carries the full token list.
            Ok(Delta::Tokens(_)) => continue,
            Ok(Delta::Done(r)) => {
                let code = match r.error.as_deref() {
                    None => 200,
                    Some(ERR_DEADLINE) => 408,
                    Some(_) => 500,
                };
                inner.state.merge_completed(&completed_metrics(&r));
                let text = inner.tokenizer.decode(&r.tokens);
                let mut o = ObjWriter::new()
                    .num("id", id as f64)
                    .str("request_id", rid)
                    .u32_arr("tokens", &r.tokens)
                    .str("text", &text)
                    .num("latency_s", r.latency)
                    .num("ttft_s", r.ttft)
                    .raw("stats", &stats_json(&r.stats));
                if let Some(e) = &r.error {
                    o = o.str("error", e);
                }
                let hdrs = [("x-request-id", rid)];
                return respond_with(&inner.state, w, code, keep, o.finish(), &hdrs);
            }
            Err(RecvTimeoutError::Timeout) => {
                // Still queued: not a stall — admission-queue wait is
                // bounded by the operator's queue depth and the client's
                // own timeout_ms (the scheduler rejects expired requests
                // at admission); a dead scheduler closes the channel. Once
                // shutdown starts, bound the remaining wait so a wedged
                // scheduler cannot deadlock the drain.
                if !admitted {
                    if inner.shutdown.load(Ordering::SeqCst) {
                        drain_waited += ADMIT_TICK;
                        if drain_waited >= inner.cfg.scheduler_wait {
                            return respond_error_retry(&inner.state, w, 503, false,
                                                       "server shutting down", rid,
                                                       DRAIN_RETRY_AFTER_SECS);
                        }
                    }
                    continue;
                }
                // Dropping ev_rx after this cancels the sequence server-side.
                return respond_error_rid(&inner.state, w, 504, false, "scheduler stalled", rid);
            }
            Err(RecvTimeoutError::Closed) => {
                return respond_error_rid(&inner.state, w, 500, false,
                                         "scheduler dropped request", rid);
            }
        }
    }
}

/// Chunked SSE-style streaming: one event per speculation block.
fn stream_response(
    id: u64,
    keep: bool,
    w: &mut TcpStream,
    inner: &Inner,
    ev_rx: &exec::Receiver<Delta>,
    rid: &str,
) -> bool {
    inner.state.count_status(200);
    let hdrs = [("x-request-id", rid)];
    let Ok(mut cw) = ChunkedWriter::start(w, 200, "text/event-stream", keep, &hdrs) else {
        return false;
    };
    // Stream preamble: the request ID arrives before any token event, so
    // a client can correlate the stream with server logs and
    // `/debug/requests/<id>` from the first byte.
    let preamble = ObjWriter::new().str("request_id", rid).finish();
    if cw.chunk(format!("data: {preamble}\n\n").as_bytes()).is_err() {
        return false;
    }
    let mut admitted = false;
    let mut drain_waited = Duration::ZERO;
    loop {
        let wait = if admitted { inner.cfg.scheduler_wait } else { ADMIT_TICK };
        match ev_rx.recv_timeout(wait) {
            Ok(Delta::Started) => admitted = true,
            Ok(Delta::Tokens(toks)) => {
                let event = ObjWriter::new()
                    .u32_arr("tokens", &toks)
                    .str("text", &inner.tokenizer.decode(&toks))
                    .finish();
                if cw.chunk(format!("data: {event}\n\n").as_bytes()).is_err() {
                    // Client hung up; dropping ev_rx cancels the sequence.
                    let mut m = ServeMetrics::default();
                    m.cancelled = 1;
                    inner.state.merge_completed(&m);
                    return false;
                }
            }
            Ok(Delta::Done(r)) => {
                inner.state.merge_completed(&completed_metrics(&r));
                let mut o = ObjWriter::new()
                    .bool("done", true)
                    .num("id", id as f64)
                    .str("request_id", rid)
                    .num("tokens_total", r.tokens.len() as f64)
                    .str("text", &inner.tokenizer.decode(&r.tokens))
                    .num("latency_s", r.latency)
                    .num("ttft_s", r.ttft)
                    .raw("stats", &stats_json(&r.stats));
                if let Some(e) = &r.error {
                    o = o.str("error", e);
                }
                let ok = cw.chunk(format!("data: {}\n\n", o.finish()).as_bytes()).is_ok();
                return cw.finish().is_ok() && ok && keep;
            }
            Err(RecvTimeoutError::Timeout) => {
                if !admitted {
                    // Queued, not stalled (see unary_response); bounded
                    // once shutdown begins.
                    if inner.shutdown.load(Ordering::SeqCst) {
                        drain_waited += ADMIT_TICK;
                        if drain_waited >= inner.cfg.scheduler_wait {
                            let _ = cw.chunk(
                                b"data: {\"done\":true,\"error\":\"server shutting down\"}\n\n",
                            );
                            let _ = cw.finish();
                            return false;
                        }
                    }
                    continue;
                }
                let _ = cw.chunk(b"data: {\"done\":true,\"error\":\"scheduler stalled\"}\n\n");
                let _ = cw.finish();
                return false;
            }
            Err(RecvTimeoutError::Closed) => {
                let _ =
                    cw.chunk(b"data: {\"done\":true,\"error\":\"scheduler dropped request\"}\n\n");
                let _ = cw.finish();
                return false;
            }
        }
    }
}

/// SSE poll cadence for `/debug/stats?stream=1`: how quickly a newly
/// sealed snapshot reaches subscribed clients.
const STATS_TICK: Duration = Duration::from_millis(250);
/// Idle ticks between SSE keepalive comments (dead-client detection when
/// the scheduler seals no new snapshots).
const STATS_KEEPALIVE_TICKS: u32 = 20;

/// `GET /debug/stats?stream=1`: push each newly sealed snapshot as one
/// SSE event over the chunked writer. The first event replays the latest
/// snapshot (if any) so clients render without waiting a full window.
fn stream_stats(
    keep: bool,
    w: &mut TcpStream,
    inner: &Inner,
    t: &Arc<crate::telemetry::Telemetry>,
) -> bool {
    inner.state.count_status(200);
    let Ok(mut cw) = ChunkedWriter::start(w, 200, "text/event-stream", keep, &[]) else {
        return false;
    };
    let mut last_seq = 0u64;
    if let Some(s) = t.latest() {
        last_seq = s.seq;
        if cw.chunk(format!("data: {}\n\n", s.to_json()).as_bytes()).is_err() {
            return false;
        }
    }
    let mut idle_ticks = 0u32;
    while !inner.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(STATS_TICK);
        // Lock-free news check: the scheduler is never contended by idle
        // subscribers.
        if t.seq() == last_seq {
            idle_ticks += 1;
            if idle_ticks >= STATS_KEEPALIVE_TICKS {
                idle_ticks = 0;
                // SSE comment line: ignored by clients, surfaces dead
                // connections as a write error.
                if cw.chunk(b": keepalive\n\n").is_err() {
                    return false;
                }
            }
            continue;
        }
        idle_ticks = 0;
        for s in t.ring() {
            if s.seq <= last_seq {
                continue;
            }
            last_seq = s.seq;
            if cw.chunk(format!("data: {}\n\n", s.to_json()).as_bytes()).is_err() {
                return false;
            }
        }
    }
    let _ = cw.finish();
    false
}

/// One completed request folded into the live aggregate.
fn completed_metrics(r: &crate::coordinator::Response) -> ServeMetrics {
    let mut m = ServeMetrics::default();
    // Acceptance-depth counts cover every block the request decoded, even
    // when it later timed out — the live `specd_accept_depth` histogram
    // sums to the aggregate `SpecStats.accepted` (pinned in
    // rust/tests/server_integration.rs).
    if !r.depth_counts.is_empty() {
        m.accept_depth = crate::metrics::Histogram::accept_depth(r.depth_counts.len() - 1);
        for (depth, &blocks) in r.depth_counts.iter().enumerate() {
            m.accept_depth.observe_n(depth as f64, blocks as u64);
        }
    }
    match r.error.as_deref() {
        None => {
            m.total_requests = 1;
            m.total_new_tokens = r.tokens.len();
            m.request_latency.push(r.latency);
            m.ttft.push(r.ttft);
            m.ttft_hist = crate::metrics::Histogram::with_bounds(&crate::metrics::TTFT_BOUNDS);
            m.ttft_hist.observe(r.ttft);
            if !r.itl.is_empty() {
                m.itl_hist = crate::metrics::Histogram::with_bounds(&crate::metrics::ITL_BOUNDS);
                for &gap in &r.itl {
                    m.itl_hist.observe(gap);
                }
                m.itl.extend_from_slice(&r.itl);
            }
            m.spec.merge(&r.stats);
        }
        Some(ERR_DEADLINE) => m.timeouts = 1,
        Some(_) => {}
    }
    m
}

fn stats_json(s: &SpecStats) -> String {
    ObjWriter::new()
        .num("blocks", s.blocks as f64)
        .num("drafted", s.drafted as f64)
        .num("accepted", s.accepted as f64)
        .num("generated", s.generated as f64)
        .num("draft_calls", s.draft_calls as f64)
        .num("target_calls", s.target_calls as f64)
        .num("block_efficiency", s.block_efficiency())
        .num("acceptance_rate", s.acceptance_rate())
        .finish()
}

// ---------------------------------------------------------------------------
// Response helpers
// ---------------------------------------------------------------------------

fn respond(
    state: &ServerState,
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    body: &[u8],
    keep: bool,
    extra: &[(&str, &str)],
) -> bool {
    state.count_status(code);
    http::write_response(w, code, content_type, body, keep, extra).is_ok() && keep
}

fn respond_with(
    state: &ServerState,
    w: &mut impl Write,
    code: u16,
    keep: bool,
    json: String,
    extra: &[(&str, &str)],
) -> bool {
    respond(state, w, code, "application/json", json.as_bytes(), keep, extra)
}

fn respond_error(state: &ServerState, w: &mut impl Write, code: u16, keep: bool, msg: &str) -> bool {
    respond_with(state, w, code, keep, ObjWriter::new().str("error", msg).finish(), &[])
}

/// Ceiling on the queue-depth-derived `Retry-After` hint: even a deeply
/// backlogged server should not push clients out more than half a minute.
const MAX_RETRY_AFTER_SECS: u64 = 30;

/// `Retry-After` hint while the server is draining or the scheduler is
/// offline: long enough to land after a restart, short enough that a
/// supervisor-managed replacement picks the retry up promptly.
const DRAIN_RETRY_AFTER_SECS: u64 = 5;

/// `Retry-After` (seconds) for backpressure rejections, derived from the
/// live admission-queue depth and drain state: an empty queue clears
/// within an iteration or two (1 s floor); a deep queue scales the hint
/// so well-behaved clients spread their retries instead of stampeding
/// the instant the first 429 expires.
fn retry_after_secs(inner: &Inner) -> u64 {
    if inner.shutdown.load(Ordering::SeqCst) {
        return DRAIN_RETRY_AFTER_SECS;
    }
    let depth = inner
        .cfg
        .scheduler_gauges
        .as_ref()
        .map_or(0, |g| g.queue_depth.load(Ordering::Relaxed));
    (1 + depth as u64 / 8).min(MAX_RETRY_AFTER_SECS)
}

/// Retryable-error response (429/503): the request ID plus a
/// `Retry-After` hint, so clients back off instead of hammering.
fn respond_error_retry(
    state: &ServerState,
    w: &mut impl Write,
    code: u16,
    keep: bool,
    msg: &str,
    rid: &str,
    retry_after: u64,
) -> bool {
    let ra = retry_after.to_string();
    let body = ObjWriter::new().str("error", msg).str("request_id", rid).finish();
    respond_with(state, w, code, keep, body, &[("x-request-id", rid), ("retry-after", &ra)])
}

/// Error response that carries the request ID in both the `x-request-id`
/// header and the JSON body, so failed requests stay correlatable.
fn respond_error_rid(
    state: &ServerState,
    w: &mut impl Write,
    code: u16,
    keep: bool,
    msg: &str,
    rid: &str,
) -> bool {
    let body = ObjWriter::new().str("error", msg).str("request_id", rid).finish();
    respond_with(state, w, code, keep, body, &[("x-request-id", rid)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_state_counts_classes() {
        let st = ServerState::default();
        st.count_status(200);
        st.count_status(201);
        st.count_status(404);
        st.count_status(429);
        st.count_status(408);
        st.count_status(500);
        assert_eq!(st.responses_2xx.load(Ordering::Relaxed), 2);
        assert_eq!(st.responses_4xx.load(Ordering::Relaxed), 3);
        assert_eq!(st.responses_5xx.load(Ordering::Relaxed), 1);
        assert_eq!(st.rejected_busy.load(Ordering::Relaxed), 1);
        assert_eq!(st.timeouts_408.load(Ordering::Relaxed), 1);
        let prom = st.prometheus();
        assert!(prom.contains("specd_http_rejected_busy_total 1"));
        assert!(prom.contains("specd_requests_total 0"));
    }

    #[test]
    fn metrics_aggregate_survives_poisoned_lock() {
        // Regression for the specd-lint no-panic sweep: a handler thread
        // that dies while holding `agg` used to poison the mutex, turning
        // every later merge/report/scrape into a panic.
        let st = std::sync::Arc::new(ServerState::default());
        let st2 = st.clone();
        let _ = std::thread::spawn(move || {
            let _g = st2.agg.lock().unwrap();
            panic!("poison the aggregate lock");
        })
        .join();
        assert!(st.agg.is_poisoned(), "test setup: lock must be poisoned");
        let mut m = ServeMetrics::default();
        m.total_requests = 1;
        st.merge_completed(&m);
        assert_eq!(st.completed_requests(), 1);
        assert!(st.prometheus().contains("specd_requests_total 1"));
        assert!(!st.aggregate_report().is_empty());
    }

    #[test]
    fn stats_json_parses() {
        let s = SpecStats { blocks: 10, drafted: 30, accepted: 20, generated: 23,
                            draft_calls: 30, target_calls: 10 };
        let v = Value::parse(&stats_json(&s)).unwrap();
        assert_eq!(v.get("blocks").as_usize(), Some(10));
        assert!((v.get("block_efficiency").as_f64().unwrap() - 2.3).abs() < 1e-12);
    }
}

//! Minimal JSON substrate (serde_json is unavailable offline).
//!
//! A full RFC 8259 value model with a recursive-descent parser and a
//! serializer. Used for the artifact manifest, vocab file, golden test
//! vectors, run configs and metric dumps. Numbers are kept as f64 (adequate
//! for every artifact we exchange: token ids, shapes, probabilities).
//!
//! For the HTTP streaming path, [`escape_fragment_into`] writes
//! escape-correct string fragments without building a [`Value`], and
//! [`ObjWriter`] assembles flat response objects incrementally.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — metric dumps diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Null` for missing keys (chainable).
    pub fn get(&self, key: &str) -> &Value {
        const NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Null` when out of bounds.
    pub fn idx(&self, i: usize) -> &Value {
        const NULL: Value = Value::Null;
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers (error messages name the missing path).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("missing string field '{key}'")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| Error::Manifest(format!("missing integer field '{key}'")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| Error::Manifest(format!("missing number field '{key}'")))
    }

    // -- construction helpers ------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    escape_fragment_into(out, s);
    out.push('"');
}

/// Append `s` to `out` as the *contents* of a JSON string — escape-correct
/// but without the surrounding quotes. This is the streaming-serializer
/// primitive: a long string can be emitted in arbitrary `&str` pieces
/// between one `"` pair, with no [`Value`] tree materialized. It also
/// backs [`ObjWriter`] (which the HTTP responses are built with).
pub fn escape_fragment_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// Incremental writer for a flat JSON object, for streaming responses where
/// building a [`Value`] per event would be wasteful. Fields are appended in
/// call order; the result of [`ObjWriter::finish`] is always a complete,
/// parseable object.
pub struct ObjWriter {
    buf: String,
    first: bool,
}

impl ObjWriter {
    pub fn new() -> ObjWriter {
        ObjWriter { buf: String::from("{"), first: true }
    }

    fn key(&mut self, key: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        write_escaped(&mut self.buf, key);
        self.buf.push(':');
    }

    pub fn str(mut self, key: &str, v: &str) -> Self {
        self.key(key);
        write_escaped(&mut self.buf, v);
        self
    }

    pub fn num(mut self, key: &str, v: f64) -> Self {
        self.key(key);
        Value::Num(v).write(&mut self.buf, None, 0);
        self
    }

    pub fn bool(mut self, key: &str, v: bool) -> Self {
        self.key(key);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn u32_arr(mut self, key: &str, xs: &[u32]) -> Self {
        self.key(key);
        self.buf.push('[');
        for (i, x) in xs.iter().enumerate() {
            if i > 0 {
                self.buf.push(',');
            }
            let _ = write!(self.buf, "{x}");
        }
        self.buf.push(']');
        self
    }

    /// Nest a pre-serialized JSON value (object, array, ...) under `key`.
    pub fn raw(mut self, key: &str, json: &str) -> Self {
        self.key(key);
        self.buf.push_str(json);
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for ObjWriter {
    fn default() -> Self {
        ObjWriter::new()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.literal("null", Value::Null),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("unterminated escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                b if b < 0x80 => s.push(b as char),
                b => {
                    // Re-decode multibyte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(Value::parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Value::Null);
        assert_eq!(v.get("missing"), &Value::Null);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"x"],"emoji":"héllo","n":-3}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_precision_preserved_in_serialization() {
        let v = Value::parse("[0, 1, 384, 23160]").unwrap();
        assert_eq!(v.to_string(), "[0,1,384,23160]");
    }

    #[test]
    fn fragment_writer_matches_whole_string_escaping() {
        // Emitting a string in pieces between one quote pair must parse to
        // the concatenation — the streaming-serializer contract.
        let pieces = ["plain ", "quo\"te", "\\back", "\nctl\u{1}", "héllo 😀"];
        let mut streamed = String::from("\"");
        for p in &pieces {
            escape_fragment_into(&mut streamed, p);
        }
        streamed.push('"');
        let whole: String = pieces.concat();
        assert_eq!(Value::parse(&streamed).unwrap(), Value::Str(whole));
    }

    #[test]
    fn obj_writer_builds_parseable_objects() {
        let s = ObjWriter::new()
            .str("text", "a\"b\nc")
            .num("latency_s", 0.125)
            .bool("done", true)
            .u32_arr("tokens", &[5, 9, 2])
            .raw("stats", r#"{"blocks":3}"#)
            .finish();
        let v = Value::parse(&s).unwrap();
        assert_eq!(v.get("text").as_str(), Some("a\"b\nc"));
        assert_eq!(v.get("latency_s").as_f64(), Some(0.125));
        assert_eq!(v.get("done").as_bool(), Some(true));
        assert_eq!(v.get("tokens").idx(2).as_usize(), Some(2));
        assert_eq!(v.get("stats").get("blocks").as_usize(), Some(3));
    }

    /// Generator over adversarial strings: ASCII, control characters,
    /// multi-byte BMP, and astral-plane codepoints.
    fn string_gen() -> crate::prop::Gen<String> {
        crate::prop::Gen::new(
            |rng| {
                let n = rng.gen_range(0, 24);
                (0..n)
                    .map(|_| match rng.gen_range(0, 5) {
                        0 => char::from_u32(rng.gen_range(0x20, 0x7f) as u32).unwrap(),
                        1 => char::from_u32(rng.gen_range(0, 0x20) as u32).unwrap(),
                        2 => char::from_u32(rng.gen_range(0xa0, 0x700) as u32).unwrap(),
                        3 => char::from_u32(rng.gen_range(0x4e00, 0x9fff) as u32).unwrap(),
                        _ => char::from_u32(rng.gen_range(0x1f300, 0x1f64f) as u32).unwrap(),
                    })
                    .collect()
            },
            |s: &String| {
                // Shrink by halving and by dropping one char.
                let chars: Vec<char> = s.chars().collect();
                let mut out = Vec::new();
                if !chars.is_empty() {
                    out.push(chars[..chars.len() / 2].iter().collect());
                    out.push(chars[1..].iter().collect());
                    out.push(chars[..chars.len() - 1].iter().collect());
                }
                out
            },
        )
    }

    #[test]
    fn prop_string_roundtrip_parse_of_serialize() {
        crate::prop::check("json-string-roundtrip", &string_gen(), 300, 11, |s| {
            let ser = Value::Str(s.clone()).to_string();
            match Value::parse(&ser) {
                Ok(Value::Str(back)) if back == *s => crate::prop::Check::Pass,
                Ok(v) => crate::prop::Check::Fail(format!("parsed to {v:?}")),
                Err(e) => crate::prop::Check::Fail(format!("parse error: {e}")),
            }
        });
    }

    #[test]
    fn prop_fragment_stream_roundtrip() {
        // Split each string at a random char boundary, stream the two
        // halves through the fragment writer, parse, compare.
        let g = string_gen();
        let mut rng = crate::rng::Pcg64::new(17);
        for _ in 0..300 {
            let s = g.sample(&mut rng);
            let chars: Vec<char> = s.chars().collect();
            let cut = if chars.is_empty() { 0 } else { rng.gen_range(0, chars.len() + 1) };
            let (a, b): (String, String) =
                (chars[..cut].iter().collect(), chars[cut..].iter().collect());
            let mut out = String::from("\"");
            escape_fragment_into(&mut out, &a);
            escape_fragment_into(&mut out, &b);
            out.push('"');
            assert_eq!(Value::parse(&out).unwrap(), Value::Str(s));
        }
    }
}
